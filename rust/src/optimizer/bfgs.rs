//! Projected BFGS with finite-difference gradients — the `optim`
//! `method = "BFGS"` analogue that fields' `MLESpatialProcess` defaults to
//! (Table IV).  The paper notes this method "is fast but not stable in many
//! cases"; our Table V / Fig 4 benches reproduce exactly that behaviour, so
//! the implementation deliberately follows the plain `optim` recipe
//! (forward-difference gradients, Armijo backtracking, bound projection)
//! rather than a hardened L-BFGS-B.

use super::{Bounds, Instrumented, OptOptions, OptResult};

pub fn minimize(
    f: impl FnMut(&[f64]) -> f64,
    bounds: Bounds,
    opts: &OptOptions,
) -> OptResult {
    let d = bounds.dim();
    assert_eq!(opts.init.len(), d, "init dimension mismatch");
    let max_evals = opts.effective_max();
    let mut obj = Instrumented::new(f, bounds);
    obj.stop = opts.stop.clone();

    let mut x = opts.init.clone();
    obj.bounds.clamp(&mut x);
    let mut fx = obj.eval(&x);

    // inverse Hessian approximation
    let mut h = vec![0.0; d * d];
    for i in 0..d {
        h[i + i * d] = 1.0;
    }

    let fd_grad = |obj: &mut Instrumented, x: &[f64], fx: f64| -> Vec<f64> {
        let mut g = vec![0.0; d];
        for i in 0..d {
            let hstep = 1e-7 * (1.0 + x[i].abs());
            let mut xp = x.to_vec();
            // step inward at the upper bound
            let (step, sign) = if xp[i] + hstep <= obj.bounds.hi[i] {
                (hstep, 1.0)
            } else {
                (-hstep, -1.0)
            };
            xp[i] += step;
            let fp = obj.eval(&xp);
            g[i] = sign * (fp - fx) / hstep;
        }
        g
    };

    let mut g = fd_grad(&mut obj, &x, fx);
    while obj.evals < max_evals && !obj.stop_requested() {
        // direction p = -H g
        let mut p = vec![0.0; d];
        for i in 0..d {
            for j in 0..d {
                p[i] -= h[i + j * d] * g[j];
            }
        }
        // backtracking line search with projection
        let mut alpha = 1.0;
        let gp: f64 = g.iter().zip(&p).map(|(a, b)| a * b).sum();
        let descent = if gp < 0.0 { gp } else { -g.iter().map(|v| v * v).sum::<f64>() };
        let dir: Vec<f64> = if gp < 0.0 { p } else { g.iter().map(|v| -v).collect() };
        let mut accepted = false;
        let mut xn = x.clone();
        let mut fn_ = fx;
        for _ in 0..30 {
            let mut cand: Vec<f64> = x.iter().zip(&dir).map(|(a, b)| a + alpha * b).collect();
            obj.bounds.clamp(&mut cand);
            let fc = obj.eval(&cand);
            if fc <= fx + 1e-4 * alpha * descent || fc < fx {
                xn = cand;
                fn_ = fc;
                accepted = true;
                break;
            }
            alpha *= 0.5;
            if obj.evals >= max_evals {
                break;
            }
        }
        if !accepted || (fx - fn_).abs() < opts.tol {
            break;
        }
        let gn = fd_grad(&mut obj, &xn, fn_);
        // BFGS update on the projected step
        let s: Vec<f64> = xn.iter().zip(&x).map(|(a, b)| a - b).collect();
        let y: Vec<f64> = gn.iter().zip(&g).map(|(a, b)| a - b).collect();
        let sy: f64 = s.iter().zip(&y).map(|(a, b)| a * b).sum();
        if sy > 1e-12 {
            // H <- (I - s y^T / sy) H (I - y s^T / sy) + s s^T / sy
            let rho = 1.0 / sy;
            // t = H y
            let mut t = vec![0.0; d];
            for i in 0..d {
                for j in 0..d {
                    t[i] += h[i + j * d] * y[j];
                }
            }
            let yty_h: f64 = y.iter().zip(&t).map(|(a, b)| a * b).sum();
            for i in 0..d {
                for j in 0..d {
                    h[i + j * d] += rho * rho * yty_h * s[i] * s[j]
                        - rho * (s[i] * t[j] + t[i] * s[j])
                        + rho * s[i] * s[j];
                }
            }
        }
        x = xn;
        fx = fn_;
        g = gn;
        let gnorm: f64 = g.iter().map(|v| v * v).sum::<f64>().sqrt();
        if gnorm < opts.tol.max(1e-12) {
            break;
        }
    }
    obj.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::testfns::sphere;

    #[test]
    fn converges_on_ill_conditioned_quadratic() {
        // f = x^2 + 100 y^2
        let f = |x: &[f64]| x[0] * x[0] + 100.0 * x[1] * x[1];
        let b = Bounds::new(vec![-10.0, -10.0], vec![10.0, 10.0]).unwrap();
        let r = minimize(
            f,
            b,
            &OptOptions {
                tol: 1e-14,
                max_iters: 0,
                init: vec![5.0, 5.0],
                stop: None,
            },
        );
        assert!(r.fx < 1e-6, "fx {}", r.fx);
    }

    #[test]
    fn boundary_start_makes_progress() {
        // paper-style: start exactly at the lower bounds
        let b = Bounds::new(vec![0.001, 0.001], vec![5.0, 5.0]).unwrap();
        let r = minimize(
            sphere(&[2.0, 3.0]),
            b,
            &OptOptions {
                tol: 1e-12,
                max_iters: 0,
                init: vec![0.001, 0.001],
                stop: None,
            },
        );
        assert!((r.x[0] - 2.0).abs() < 1e-3 && (r.x[1] - 3.0).abs() < 1e-3, "{:?}", r.x);
    }
}
