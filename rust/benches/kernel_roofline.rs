//! **Kernel roofline** — GFLOP/s of the BLAS-3 building blocks
//! (gemm / syrk / trsm / potrf) at tile-relevant sizes, f64 and f32,
//! dispatched-SIMD vs forced-scalar, plus end-to-end exact-MLE
//! evaluation time under both dispatch paths and the MP-vs-exact
//! time per evaluation.
//!
//! Emits `BENCH_kernels.json` (override with `BENCH_OUT`); schema and
//! expectations in EXPERIMENTS.md §Kernel roofline.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, DistanceMetric, Location};
use exageostat::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use exageostat::linalg::blas::{
    detected_simd, dgemm_raw_at, dpotrf_raw, dsyrk_ln_raw, dtrsm_rltn_raw, gemm_mp_at,
    set_simd_override, simd_level, MatMut, MatRef, SimdLevel, Trans,
};
use exageostat::pipeline::set_fuse_override;
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use std::sync::Arc;

/// One kernel measurement at a fixed dispatch level.
fn time_op(reps: usize, k: usize, mut f: impl FnMut()) -> f64 {
    time_median(k, || {
        for _ in 0..reps {
            f();
        }
    })
}

struct KernelRow {
    op: &'static str,
    prec: &'static str,
    b: usize,
    gflops_dispatch: f64,
    gflops_scalar: f64,
}

fn main() {
    let quick = quick();
    let sizes: &[usize] = if quick { &[64, 128] } else { &[64, 128, 256] };
    let medians = if quick { 3 } else { 5 };
    let mut rng = Pcg64::seed_from_u64(0xBEEF);
    let mut rows: Vec<KernelRow> = Vec::new();

    println!(
        "Kernel roofline — simd detected: {}, active: {}",
        detected_simd().name(),
        simd_level().name()
    );
    header(&["op", "prec", "b", "GF/s simd", "GF/s scalar", "ratio"]);

    for &b in sizes {
        let reps = (256 / b).pow(3).max(1);
        let a: Vec<f64> = (0..b * b).map(|_| rng.normal()).collect();
        let bb: Vec<f64> = (0..b * b).map(|_| rng.normal()).collect();
        let mut c = vec![0.0f64; b * b];
        // SPD matrix + factor for trsm/potrf.
        let mut spd = vec![0.0f64; b * b];
        dgemm_raw_at(
            SimdLevel::Scalar,
            Trans::N,
            Trans::T,
            b,
            b,
            b,
            1.0,
            &a,
            b,
            &a,
            b,
            0.0,
            &mut spd,
            b,
        );
        for i in 0..b {
            spd[i + i * b] += b as f64;
        }
        let mut lfac = spd.clone();
        dpotrf_raw(b, &mut lfac, b).unwrap();

        // The per-op measurement under an explicit level: gemm/syrk/trsm
        // take the level via the `_at` APIs where available, the rest via
        // the process-wide override.
        let mut measure = |level: SimdLevel| -> [f64; 4] {
            assert!(set_simd_override(Some(level)));
            let t_gemm = time_op(reps, medians, || {
                dgemm_raw_at(
                    level,
                    Trans::N,
                    Trans::T,
                    b,
                    b,
                    b,
                    -1.0,
                    &a,
                    b,
                    &bb,
                    b,
                    1.0,
                    &mut c,
                    b,
                );
            });
            let t_syrk = time_op(reps, medians, || {
                dsyrk_ln_raw(b, b, -1.0, &a, b, 1.0, &mut c, b);
            });
            // Restore the right-hand side every rep: repeated in-place
            // L^{-T} applications would shrink it toward denormals and
            // trip the kernels' nonzero short-circuits.
            let mut bt = bb.clone();
            let t_trsm = time_op(reps, medians, || {
                bt.copy_from_slice(&bb);
                dtrsm_rltn_raw(b, b, &lfac, b, &mut bt, b);
            });
            // Pre-allocated scratch: only the restore copy stays inside
            // the timing (cheap O(b²) next to the O(b³/3) factorization);
            // no per-iteration heap traffic skews the GFLOP/s telemetry.
            let mut scratch = spd.clone();
            let t_potrf = time_op(reps, medians, || {
                scratch.copy_from_slice(&spd);
                dpotrf_raw(b, &mut scratch, b).unwrap();
            });
            assert!(set_simd_override(None));
            let fb = b as f64;
            [
                2.0 * fb * fb * fb / t_gemm * reps as f64 / 1e9,
                fb * fb * fb / t_syrk * reps as f64 / 1e9,
                fb * fb * fb / t_trsm * reps as f64 / 1e9,
                fb * fb * fb / 3.0 / t_potrf * reps as f64 / 1e9,
            ]
        };
        let simd = measure(detected_simd());
        let scal = measure(SimdLevel::Scalar);
        for (i, op) in ["gemm", "syrk", "trsm", "potrf"].into_iter().enumerate() {
            rows.push(KernelRow {
                op,
                prec: "f64",
                b,
                gflops_dispatch: simd[i],
                gflops_scalar: scal[i],
            });
        }

        // f32 gemm through the mixed-precision path (f32 operands and
        // destination): the MP variant's off-band compute kernel.
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = bb.iter().map(|&v| v as f32).collect();
        let mut c32 = vec![0.0f32; b * b];
        let mut measure32 = |level: SimdLevel| -> f64 {
            let t = time_op(reps, medians, || {
                gemm_mp_at(
                    level,
                    Trans::N,
                    Trans::T,
                    b,
                    b,
                    b,
                    -1.0,
                    MatRef::F32(&a32),
                    b,
                    MatRef::F32(&b32),
                    b,
                    1.0,
                    MatMut::F32(&mut c32),
                    b,
                );
            });
            2.0 * (b as f64).powi(3) / t * reps as f64 / 1e9
        };
        rows.push(KernelRow {
            op: "gemm",
            prec: "f32",
            b,
            gflops_dispatch: measure32(detected_simd()),
            gflops_scalar: measure32(SimdLevel::Scalar),
        });

        for r in rows.iter().filter(|r| r.b == b) {
            row(&[
                r.op.into(),
                r.prec.into(),
                format!("{}", r.b),
                s2(r.gflops_dispatch),
                s2(r.gflops_scalar),
                s2(r.gflops_dispatch / r.gflops_scalar),
            ]);
        }
    }

    // -----------------------------------------------------------------
    // End-to-end: warm exact-session evaluation, dispatch vs scalar,
    // and the MP (band 1) evaluation under dispatch.
    // -----------------------------------------------------------------
    let n = if quick { 240 } else { 600 };
    // Keep several tile rows so MP band=1 really has f32 off-band tiles.
    let ts = if quick { 64 } else { 128 };
    let theta = [1.0, 0.1, 0.5];
    let locs: Vec<Location> = (0..n)
        .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
        .collect();
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let problem = Problem {
        kernel: kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(locs),
        z: Arc::new(z),
        metric: DistanceMetric::Euclidean,
    };
    let ctx = ExecCtx::new(2, ts, Policy::Prio);
    let k = if quick { 2 } else { 4 };

    let mut exact = EvalSession::new(&problem, Variant::Exact, &ctx).unwrap();
    exact.eval(&theta).unwrap(); // warm caches + workspaces
    assert!(set_simd_override(Some(SimdLevel::Scalar)));
    let t_scalar = time_median(k, || {
        exact.eval(&theta).unwrap();
    });
    assert!(set_simd_override(None));
    let t_dispatch = time_median(k, || {
        exact.eval(&theta).unwrap();
    });

    let mut mp = EvalSession::new(&problem, Variant::Mp { band: 1 }, &ctx).unwrap();
    mp.eval(&theta).unwrap();
    let t_mp = time_median(k, || {
        mp.eval(&theta).unwrap();
    });

    println!(
        "\nexact warm eval n={n} ts={ts}: scalar {:.4}s, dispatch {:.4}s ({:.2}x); \
         mp band=1 {:.4}s (exact/mp {:.2}x)",
        t_scalar,
        t_dispatch,
        t_scalar / t_dispatch,
        t_mp,
        t_dispatch / t_mp
    );

    // -----------------------------------------------------------------
    // Fusion planner: warm eval per variant, fused vs unfused plans over
    // the same session.  The exact n=4096 case is the CI regression
    // gate's wall (fused warm time must not exceed unfused) and runs at
    // full size even under --quick; the other variants shrink.
    // -----------------------------------------------------------------
    struct FusionRow {
        variant: &'static str,
        n: usize,
        ts: usize,
        fused_s: f64,
        unfused_s: f64,
    }
    let n_small = if quick { 480 } else { 960 };
    let fusion_cases: [(&'static str, Variant, usize, usize); 4] = [
        ("exact", Variant::Exact, 4096, 256),
        ("dst", Variant::Dst { band: 1 }, n_small, 64),
        ("mp", Variant::Mp { band: 1 }, n_small, 64),
        (
            "tlr",
            Variant::Tlr {
                tol: 1e-7,
                max_rank: 48,
            },
            n_small,
            64,
        ),
    ];
    println!("\nFusion planner — warm eval per variant");
    header(&["variant", "n", "ts", "fused s", "unfused s", "speedup"]);
    let mut fusion_rows: Vec<FusionRow> = Vec::new();
    for (name, variant, fn_, fts) in fusion_cases {
        let locs: Vec<Location> = (0..fn_)
            .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
            .collect();
        let z: Vec<f64> = (0..fn_).map(|_| rng.normal()).collect();
        let fproblem = Problem {
            kernel: kernel_by_name("ugsm-s").unwrap().into(),
            locs: Arc::new(locs),
            z: Arc::new(z),
            metric: DistanceMetric::Euclidean,
        };
        let fctx = ExecCtx::new(4, fts, Policy::Prio);
        let mut sess = EvalSession::new(&fproblem, variant, &fctx).unwrap();
        let mut timed = |fuse: bool| -> f64 {
            set_fuse_override(Some(fuse));
            sess.eval(&theta).unwrap(); // warm under this plan shape
            time_median(k, || {
                sess.eval(&theta).unwrap();
            })
        };
        // Unfused first: any residual warm-up drift then favors neither
        // side systematically (each mode gets its own warm eval).
        let unfused_s = timed(false);
        let fused_s = timed(true);
        set_fuse_override(None);
        row(&[
            name.into(),
            format!("{fn_}"),
            format!("{fts}"),
            s(fused_s),
            s(unfused_s),
            s2(unfused_s / fused_s),
        ]);
        fusion_rows.push(FusionRow {
            variant: name,
            n: fn_,
            ts: fts,
            fused_s,
            unfused_s,
        });
    }

    // -----------------------------------------------------------------
    // BENCH_kernels.json
    // -----------------------------------------------------------------
    let jnum = |v: f64| -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "null".into()
        }
    };
    let kernel_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"op\": \"{}\", \"prec\": \"{}\", \"b\": {}, \
                 \"gflops_dispatch\": {}, \"gflops_scalar\": {}, \"ratio\": {}}}",
                r.op,
                r.prec,
                r.b,
                jnum(r.gflops_dispatch),
                jnum(r.gflops_scalar),
                jnum(r.gflops_dispatch / r.gflops_scalar)
            )
        })
        .collect();
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"kernel_roofline\",\n");
    json.push_str(&format!(
        "  \"simd_detected\": \"{}\",\n  \"simd_active\": \"{}\",\n",
        detected_simd().name(),
        simd_level().name()
    ));
    json.push_str(&format!("  \"kernels\": [\n{}\n  ],\n", kernel_rows.join(",\n")));
    let fusion_json: Vec<String> = fusion_rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"variant\": \"{}\", \"n\": {}, \"ts\": {}, \
                 \"fused_s\": {}, \"unfused_s\": {}, \"speedup\": {}}}",
                r.variant,
                r.n,
                r.ts,
                jnum(r.fused_s),
                jnum(r.unfused_s),
                jnum(r.unfused_s / r.fused_s)
            )
        })
        .collect();
    json.push_str(&format!("  \"fusion\": [\n{}\n  ],\n", fusion_json.join(",\n")));
    json.push_str(&format!(
        "  \"mle\": {{\n    \"n\": {n}, \"ts\": {ts},\n    \
         \"exact_eval_scalar_s\": {},\n    \"exact_eval_dispatch_s\": {},\n    \
         \"dispatch_speedup\": {},\n    \"mp_eval_dispatch_s\": {},\n    \
         \"mp_vs_exact\": {}\n  }}\n",
        jnum(t_scalar),
        jnum(t_dispatch),
        jnum(t_scalar / t_dispatch),
        jnum(t_mp),
        jnum(t_dispatch / t_mp)
    ));
    json.push_str("}\n");
    let out = bench_out_path("BENCH_kernels.json");
    std::fs::write(&out, &json)
        .unwrap_or_else(|e| eprintln!("cannot write {}: {e}", out.display()));
    println!("telemetry written to {}", out.display());
}
