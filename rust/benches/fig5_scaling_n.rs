//! **Fig 5** — execution time per iteration as n grows, ExaGeoStatR vs the
//! GeoR-like and fields-like baselines, plus the ratio panel (right panel
//! of the figure).  The paper runs n up to 90,000 (and stops the R
//! packages at 22,500 / 17 hours); sizes here are scaled to the testbed.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::baselines::dense_negloglik;
use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{ExecCtx, Problem, Variant};
use exageostat::scheduler::pool::Policy;
use exageostat::simulation::simulate_data_exact;
use std::sync::Arc;

fn main() {
    let quick = quick();
    let sizes: &[usize] = if quick {
        &[100, 400, 900]
    } else {
        &[100, 400, 900, 1600, 2500, 3600]
    };
    let theta = [1.0, 0.1, 0.5];
    let kernel: Arc<dyn exageostat::covariance::CovKernel> =
        Arc::from(kernel_by_name("ugsm-s").unwrap());
    let ctx = ExecCtx::new(2, 160, Policy::Prio);

    println!("Fig 5 — time per iteration (s) vs n; ratios vs exageostat (log10 scale in paper)");
    header(&["n", "exageostat", "geor-like", "fields-lik", "r_geor", "r_fields"]);
    for &n in sizes {
        let data =
            simulate_data_exact(kernel.clone(), &theta, n, DistanceMetric::Euclidean, 0, &ctx)
                .unwrap();
        let problem = Problem {
            kernel: kernel.clone(),
            locs: Arc::new(data.locs.clone()),
            z: Arc::new(data.z.clone()),
            metric: DistanceMetric::Euclidean,
        };
        let reps = if n <= 900 { 3 } else { 1 };
        let t_exa = time_median(reps, || {
            let _ = exageostat::likelihood::loglik(&problem, &theta, Variant::Exact, &ctx).unwrap();
        });
        // The R baselines evaluate the same dense likelihood sequentially;
        // GeoR additionally recomputes the mean profile (negligible), and
        // fields at fixed nu skips nothing per evaluation — their Fig 5 gap
        // vs ExaGeoStat comes from the sequential dense path.
        let t_geor = time_median(reps, || {
            let _ = dense_negloglik(&data.locs, &data.z, &theta, DistanceMetric::Euclidean);
        });
        let t_fields = t_geor; // same evaluation kernel (see comment)
        row(&[
            format!("{n}"),
            s(t_exa),
            s(t_geor),
            s(t_fields),
            s2(t_geor / t_exa),
            s2(t_fields / t_exa),
        ]);
    }
    println!(
        "\nshape check (paper): exageostat per-iteration time grows ~n^3 with a constant\n\
         factor well below the sequential baselines; at n=22,500 the paper reports 33x/92x\n\
         (their 8-core testbed). Here the gap comes from the tiled blocked kernels; on a\n\
         single-core testbed the ratio reflects kernel efficiency, not parallelism — see\n\
         fig3 for the DES core-scaling projection."
    );
}
