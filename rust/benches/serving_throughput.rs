//! **Serving throughput** — the concurrent-coordinator benchmark behind
//! the persistent-runtime refactor: K client threads submit a mixed
//! MLE + predict + simulate workload to **one** shared `Runtime`
//! (`Coordinator`), versus the pre-refactor serving model of one fresh
//! worker pool per job, run sequentially — plus the **streaming** path
//! (`serve_stream` over a JSONL pipe with a bounded in-flight window)
//! and a cancellation round (every third ticket cancelled mid-flight).
//!
//! Emits `BENCH_serving.json` (override the path with `BENCH_OUT`):
//! requests/sec, p50/p95/p99 latency per mode, and cancelled-request
//! counts.  `BENCH_QUICK` (or `--quick`) shrinks the workload for CI.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::api::{Hardware, MleOptions};
use exageostat::coordinator::{
    serve_stream, Client, Completion, Coordinator, DataSpec, Request, RequestKind, ServeOptions,
};
use exageostat::likelihood::Variant;
use exageostat::scheduler::pool::Policy;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn workload(n: usize, count: usize, max_iters: usize) -> Vec<Request> {
    (0..count)
        .map(|i| {
            let data = DataSpec {
                n,
                seed: (i % 3) as u64, // 3 distinct datasets -> real cache traffic
                ..DataSpec::default()
            };
            let kind = match i % 3 {
                0 => RequestKind::Mle {
                    variant: Variant::Exact,
                    opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, max_iters),
                },
                1 => RequestKind::Predict { grid: 6 },
                _ => RequestKind::Simulate,
            };
            Request {
                data: data.into(),
                kind,
                priority: (i % 4) as u8,
            }
        })
        .collect()
}

/// The same workload as JSONL lines (what the streaming path ingests).
fn workload_jsonl(n: usize, count: usize, max_iters: usize) -> String {
    (0..count)
        .map(|i| {
            let seed = i % 3;
            match i % 3 {
                0 => format!(
                    "{{\"type\":\"mle\",\"n\":{n},\"seed\":{seed},\"max_iters\":{max_iters},\
                     \"clb\":[0.01,0.01,0.01],\"priority\":{}}}\n",
                    i % 4
                ),
                1 => format!("{{\"type\":\"predict\",\"n\":{n},\"seed\":{seed},\"grid\":6}}\n"),
                _ => format!("{{\"type\":\"simulate\",\"n\":{n},\"seed\":{seed}}}\n"),
            }
        })
        .collect()
}

/// K client threads, one shared coordinator/runtime.
fn run_concurrent(hw: &Hardware, reqs: &[Request], clients: usize) -> (f64, Vec<f64>) {
    let coord = Coordinator::new(hw.clone());
    let next = AtomicUsize::new(0);
    let lats = Mutex::new(Vec::with_capacity(reqs.len()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= reqs.len() {
                    break;
                }
                let r = coord.run(reqs[i].clone()).expect("request");
                lats.lock().unwrap().push(r.wall_s);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    (wall, lats.into_inner().unwrap())
}

/// Pre-refactor model: every request stands up (and tears down) its own
/// pool; requests run back to back.
fn run_sequential(hw: &Hardware, reqs: &[Request]) -> (f64, Vec<f64>) {
    let mut lats = Vec::with_capacity(reqs.len());
    let t0 = Instant::now();
    for r in reqs {
        let coord = Coordinator::new(hw.clone());
        let resp = coord.run(r.clone()).expect("request");
        lats.push(resp.wall_s);
        coord.shutdown();
    }
    (t0.elapsed().as_secs_f64(), lats)
}

/// Streaming path: `serve_stream` over an in-memory JSONL "pipe" with a
/// bounded in-flight window.  Returns (wall, sorted latencies).
fn run_streaming(hw: &Hardware, jsonl: &str, clients: usize, window: usize) -> (f64, Vec<f64>) {
    let coord = Arc::new(Coordinator::new(hw.clone()));
    let client = Client::new(coord.clone(), clients);
    let mut reader = std::io::BufReader::new(jsonl.as_bytes());
    let opts = ServeOptions {
        window,
        depth_limit: None,
    };
    let t0 = Instant::now();
    let summary = serve_stream(&client, &mut reader, &opts, |_, _| {}).expect("stream");
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(summary.failed, 0, "streaming workload must not fail");
    client.shutdown();
    coord.shutdown();
    (wall, summary.latencies_s)
}

/// Cancellation round: submit everything through tickets, cancel every
/// second one immediately, wait for the rest.  Returns (completed,
/// cancelled, tasks_executed).  The stride is 2 on purpose: the
/// workload assigns request *kinds* by `i % 3`, so a stride of 3 would
/// only ever cancel MLEs — 2 exercises the predict and simulate
/// cancellation paths too.
fn run_cancelling(hw: &Hardware, reqs: &[Request]) -> (usize, usize, u64) {
    let coord = Arc::new(Coordinator::new(hw.clone()));
    let client = Client::new(coord.clone(), 4);
    let tickets: Vec<_> = reqs.iter().map(|r| client.submit(r.clone())).collect();
    for (i, t) in tickets.iter().enumerate() {
        if i % 2 == 0 {
            t.cancel();
        }
    }
    let mut done = 0usize;
    let mut cancelled = 0usize;
    for t in &tickets {
        match t.wait() {
            Completion::Done(_) => done += 1,
            Completion::Cancelled => cancelled += 1,
            Completion::Failed(e) => panic!("bench request failed: {e}"),
        }
    }
    let tasks = coord.runtime().tasks_executed();
    client.shutdown();
    coord.shutdown();
    (done, cancelled, tasks)
}

fn pct(lat: &mut [f64], p: f64) -> f64 {
    lat.sort_by(f64::total_cmp);
    exageostat::testkit::percentile(lat, p)
}

fn main() {
    let quick = quick();
    let n = if quick { 100 } else { 250 };
    let count = if quick { 6 } else { 18 };
    let max_iters = if quick { 4 } else { 12 };
    let clients = 4;
    let hw = Hardware {
        ncores: 2,
        ts: 64,
        policy: Policy::Prio,
        ..Hardware::default()
    };
    let reqs = workload(n, count, max_iters);

    println!(
        "Serving throughput — {count} requests (n={n}, {max_iters} MLE iters), \
         {clients} clients, {} workers",
        hw.ncores
    );
    header(&["mode", "wall s", "req/s", "p50 s", "p95 s", "p99 s"]);

    let (seq_wall, mut seq_lat) = run_sequential(&hw, &reqs);
    let seq_rps = count as f64 / seq_wall;
    let (seq_p50, seq_p95, seq_p99) = (
        pct(&mut seq_lat, 0.50),
        pct(&mut seq_lat, 0.95),
        pct(&mut seq_lat, 0.99),
    );
    row(&[
        "per-job".into(),
        s(seq_wall),
        s2(seq_rps),
        s(seq_p50),
        s(seq_p95),
        s(seq_p99),
    ]);

    let (con_wall, mut con_lat) = run_concurrent(&hw, &reqs, clients);
    let con_rps = count as f64 / con_wall;
    let (con_p50, con_p95, con_p99) = (
        pct(&mut con_lat, 0.50),
        pct(&mut con_lat, 0.95),
        pct(&mut con_lat, 0.99),
    );
    row(&[
        "shared".into(),
        s(con_wall),
        s2(con_rps),
        s(con_p50),
        s(con_p95),
        s(con_p99),
    ]);

    let jsonl = workload_jsonl(n, count, max_iters);
    let window = 2 * clients;
    let (str_wall, mut str_lat) = run_streaming(&hw, &jsonl, clients, window);
    let str_rps = count as f64 / str_wall;
    let (str_p50, str_p95, str_p99) = (
        pct(&mut str_lat, 0.50),
        pct(&mut str_lat, 0.95),
        pct(&mut str_lat, 0.99),
    );
    row(&[
        "streaming".into(),
        s(str_wall),
        s2(str_rps),
        s(str_p50),
        s(str_p95),
        s(str_p99),
    ]);

    let (can_done, can_cancelled, can_tasks) = run_cancelling(&hw, &reqs);
    println!(
        "\ncancellation round: {can_done} completed, {can_cancelled} cancelled \
         (every 2nd ticket, mixed kinds), {can_tasks} tasks executed"
    );
    println!(
        "shape check: the shared persistent runtime should serve at >= the\n\
         sequential per-job-pool rate (cache reuse + no spawn/join per job);\n\
         here {:.2}x (streaming {:.2}x).",
        con_rps / seq_rps.max(1e-12),
        str_rps / seq_rps.max(1e-12)
    );

    let json = format!(
        "{{\n  \"bench\": \"serving_throughput\",\n  \"n\": {n},\n  \
         \"requests\": {count},\n  \"clients\": {clients},\n  \
         \"ncores\": {},\n  \"mle_max_iters\": {max_iters},\n  \
         \"shared\": {{\"wall_s\": {con_wall}, \"req_per_s\": {con_rps}, \
         \"p50_s\": {con_p50}, \"p95_s\": {con_p95}, \"p99_s\": {con_p99}}},\n  \
         \"sequential_per_job\": {{\"wall_s\": {seq_wall}, \"req_per_s\": {seq_rps}, \
         \"p50_s\": {seq_p50}, \"p95_s\": {seq_p95}, \"p99_s\": {seq_p99}}},\n  \
         \"streaming\": {{\"wall_s\": {str_wall}, \"req_per_s\": {str_rps}, \
         \"p50_s\": {str_p50}, \"p95_s\": {str_p95}, \"p99_s\": {str_p99}, \
         \"window\": {window}}},\n  \
         \"cancellation\": {{\"completed\": {can_done}, \"cancelled\": {can_cancelled}, \
         \"tasks_executed\": {can_tasks}}}\n}}\n",
        hw.ncores
    );
    let out = bench_out_path("BENCH_serving.json");
    std::fs::write(&out, &json)
        .unwrap_or_else(|e| eprintln!("cannot write {}: {e}", out.display()));
    println!("telemetry written to {}", out.display());
}
