//! **Serving throughput** — the concurrent-coordinator benchmark behind
//! the persistent-runtime refactor: K client threads submit a mixed
//! MLE + predict + simulate workload to **one** shared `Runtime`
//! (`Coordinator`), versus the pre-refactor serving model of one fresh
//! worker pool per job, run sequentially.
//!
//! Emits `BENCH_serving.json` (override the path with `BENCH_OUT`):
//! requests/sec and p50/p95 latency for both modes.  `BENCH_QUICK`
//! (or `--quick`) shrinks the workload for CI.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::api::{Hardware, MleOptions};
use exageostat::coordinator::{Coordinator, DataSpec, Request, RequestKind};
use exageostat::likelihood::Variant;
use exageostat::scheduler::pool::Policy;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

fn workload(n: usize, count: usize, max_iters: usize) -> Vec<Request> {
    (0..count)
        .map(|i| {
            let data = DataSpec {
                n,
                seed: (i % 3) as u64, // 3 distinct datasets -> real cache traffic
                ..DataSpec::default()
            };
            let kind = match i % 3 {
                0 => RequestKind::Mle {
                    variant: Variant::Exact,
                    opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, max_iters),
                },
                1 => RequestKind::Predict { grid: 6 },
                _ => RequestKind::Simulate,
            };
            Request {
                data,
                kind,
                priority: (i % 4) as u8,
            }
        })
        .collect()
}

/// K client threads, one shared coordinator/runtime.
fn run_concurrent(hw: &Hardware, reqs: &[Request], clients: usize) -> (f64, Vec<f64>) {
    let coord = Coordinator::new(hw.clone());
    let next = AtomicUsize::new(0);
    let lats = Mutex::new(Vec::with_capacity(reqs.len()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= reqs.len() {
                    break;
                }
                let r = coord.run(reqs[i].clone()).expect("request");
                lats.lock().unwrap().push(r.wall_s);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    (wall, lats.into_inner().unwrap())
}

/// Pre-refactor model: every request stands up (and tears down) its own
/// pool; requests run back to back.
fn run_sequential(hw: &Hardware, reqs: &[Request]) -> (f64, Vec<f64>) {
    let mut lats = Vec::with_capacity(reqs.len());
    let t0 = Instant::now();
    for r in reqs {
        let coord = Coordinator::new(hw.clone());
        let resp = coord.run(r.clone()).expect("request");
        lats.push(resp.wall_s);
        coord.shutdown();
    }
    (t0.elapsed().as_secs_f64(), lats)
}

fn pct(lat: &mut [f64], p: f64) -> f64 {
    lat.sort_by(f64::total_cmp);
    exageostat::testkit::percentile(lat, p)
}

fn main() {
    let quick = quick();
    let n = if quick { 100 } else { 250 };
    let count = if quick { 6 } else { 18 };
    let max_iters = if quick { 4 } else { 12 };
    let clients = 4;
    let hw = Hardware {
        ncores: 2,
        ts: 64,
        policy: Policy::Prio,
        ..Hardware::default()
    };
    let reqs = workload(n, count, max_iters);

    println!(
        "Serving throughput — {count} requests (n={n}, {max_iters} MLE iters), \
         {clients} clients, {} workers",
        hw.ncores
    );
    header(&["mode", "wall s", "req/s", "p50 s", "p95 s"]);

    let (seq_wall, mut seq_lat) = run_sequential(&hw, &reqs);
    let seq_rps = count as f64 / seq_wall;
    let (seq_p50, seq_p95) = (pct(&mut seq_lat, 0.50), pct(&mut seq_lat, 0.95));
    row(&[
        "per-job".into(),
        s(seq_wall),
        s2(seq_rps),
        s(seq_p50),
        s(seq_p95),
    ]);

    let (con_wall, mut con_lat) = run_concurrent(&hw, &reqs, clients);
    let con_rps = count as f64 / con_wall;
    let (con_p50, con_p95) = (pct(&mut con_lat, 0.50), pct(&mut con_lat, 0.95));
    row(&[
        "shared".into(),
        s(con_wall),
        s2(con_rps),
        s(con_p50),
        s(con_p95),
    ]);

    println!(
        "\nshape check: the shared persistent runtime should serve at >= the\n\
         sequential per-job-pool rate (cache reuse + no spawn/join per job);\n\
         here {:.2}x.",
        con_rps / seq_rps.max(1e-12)
    );

    let json = format!(
        "{{\n  \"bench\": \"serving_throughput\",\n  \"n\": {n},\n  \
         \"requests\": {count},\n  \"clients\": {clients},\n  \
         \"ncores\": {},\n  \"mle_max_iters\": {max_iters},\n  \
         \"shared\": {{\"wall_s\": {con_wall}, \"req_per_s\": {con_rps}, \
         \"p50_s\": {con_p50}, \"p95_s\": {con_p95}}},\n  \
         \"sequential_per_job\": {{\"wall_s\": {seq_wall}, \"req_per_s\": {seq_rps}, \
         \"p50_s\": {seq_p50}, \"p95_s\": {seq_p95}}}\n}}\n",
        hw.ncores
    );
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serving.json".into());
    std::fs::write(&out, &json).unwrap_or_else(|e| eprintln!("cannot write {out}: {e}"));
    println!("telemetry written to {out}");
}
