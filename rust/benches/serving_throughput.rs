//! **Serving throughput** — the concurrent-coordinator benchmark behind
//! the persistent-runtime refactor: K client threads submit a mixed
//! MLE + predict + simulate workload to **one** shared `Runtime`
//! (`Coordinator`), versus the pre-refactor serving model of one fresh
//! worker pool per job, run sequentially — plus the **streaming** path
//! (`serve_stream` over a JSONL pipe with a bounded in-flight window),
//! a cancellation round (every second ticket cancelled mid-flight), and
//! a **shard-scaling** mode (`ShardedCoordinator` at 1/2/4 shards, one
//! 2-worker runtime per shard).
//!
//! Emits `BENCH_serving.json` (override the path with `BENCH_OUT`):
//! requests/sec, p50/p95/p99 latency per mode, cancelled-request
//! counts, and req/s per shard count with its speedup over one shard.
//! `BENCH_QUICK` (or `--quick`) shrinks the workload for CI.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::api::{Hardware, MleOptions};
use exageostat::coordinator::{
    serve_stream, Client, Completion, Coordinator, DataSpec, Dispatch, Request, RequestKind,
    ServeOptions, ShardedCoordinator,
};
use exageostat::likelihood::Variant;
use exageostat::scheduler::pool::Policy;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn workload(n: usize, count: usize, max_iters: usize) -> Vec<Request> {
    (0..count)
        .map(|i| {
            let data = DataSpec {
                n,
                seed: (i % 3) as u64, // 3 distinct datasets -> real cache traffic
                ..DataSpec::default()
            };
            let kind = match i % 3 {
                0 => RequestKind::Mle {
                    variant: Variant::Exact,
                    opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, max_iters),
                },
                1 => RequestKind::Predict { grid: 6 },
                _ => RequestKind::Simulate,
            };
            Request {
                data: data.into(),
                kind,
                priority: (i % 4) as u8,
                deadline_ms: None,
            }
        })
        .collect()
}

/// The same workload as JSONL lines (what the streaming path ingests).
fn workload_jsonl(n: usize, count: usize, max_iters: usize) -> String {
    (0..count)
        .map(|i| {
            let seed = i % 3;
            match i % 3 {
                0 => format!(
                    "{{\"type\":\"mle\",\"n\":{n},\"seed\":{seed},\"max_iters\":{max_iters},\
                     \"clb\":[0.01,0.01,0.01],\"priority\":{}}}\n",
                    i % 4
                ),
                1 => format!("{{\"type\":\"predict\",\"n\":{n},\"seed\":{seed},\"grid\":6}}\n"),
                _ => format!("{{\"type\":\"simulate\",\"n\":{n},\"seed\":{seed}}}\n"),
            }
        })
        .collect()
}

/// K client threads, one shared coordinator/runtime.
fn run_concurrent(hw: &Hardware, reqs: &[Request], clients: usize) -> (f64, Vec<f64>) {
    let coord = Coordinator::new(hw.clone());
    let next = AtomicUsize::new(0);
    let lats = Mutex::new(Vec::with_capacity(reqs.len()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= reqs.len() {
                    break;
                }
                let r = coord.run(reqs[i].clone()).expect("request");
                lats.lock().unwrap().push(r.wall_s);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    (wall, lats.into_inner().unwrap())
}

/// Pre-refactor model: every request stands up (and tears down) its own
/// pool; requests run back to back.
fn run_sequential(hw: &Hardware, reqs: &[Request]) -> (f64, Vec<f64>) {
    let mut lats = Vec::with_capacity(reqs.len());
    let t0 = Instant::now();
    for r in reqs {
        let coord = Coordinator::new(hw.clone());
        let resp = coord.run(r.clone()).expect("request");
        lats.push(resp.wall_s);
        coord.shutdown();
    }
    (t0.elapsed().as_secs_f64(), lats)
}

/// Streaming path: `serve_stream` over an in-memory JSONL "pipe" with a
/// bounded in-flight window.  Returns (wall, sorted latencies).
fn run_streaming(hw: &Hardware, jsonl: &str, clients: usize, window: usize) -> (f64, Vec<f64>) {
    let coord = Arc::new(Coordinator::new(hw.clone()));
    let client = Client::new(coord.clone(), clients);
    let mut reader = std::io::BufReader::new(jsonl.as_bytes());
    let opts = ServeOptions {
        window,
        depth_limit: None,
    };
    let t0 = Instant::now();
    let summary = serve_stream(&client, &mut reader, &opts, |_, _| {}).expect("stream");
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(summary.failed, 0, "streaming workload must not fail");
    client.shutdown();
    coord.shutdown();
    (wall, summary.latencies_s)
}

/// Cancellation round: submit everything through tickets, cancel every
/// second one immediately, wait for the rest.  Returns (completed,
/// cancelled, tasks_executed).  The stride is 2 on purpose: the
/// workload assigns request *kinds* by `i % 3`, so a stride of 3 would
/// only ever cancel MLEs — 2 exercises the predict and simulate
/// cancellation paths too.
fn run_cancelling(hw: &Hardware, reqs: &[Request]) -> (usize, usize, u64) {
    let coord = Arc::new(Coordinator::new(hw.clone()));
    let client = Client::new(coord.clone(), 4);
    let tickets: Vec<_> = reqs.iter().map(|r| client.submit(r.clone())).collect();
    for (i, t) in tickets.iter().enumerate() {
        if i % 2 == 0 {
            t.cancel();
        }
    }
    let mut done = 0usize;
    let mut cancelled = 0usize;
    for t in &tickets {
        match t.wait() {
            Completion::Done(_) => done += 1,
            Completion::Cancelled => cancelled += 1,
            Completion::TimedOut => panic!("bench request timed out (no deadlines set)"),
            Completion::Failed(e) => panic!("bench request failed: {e}"),
        }
    }
    let tasks = coord.runtime().tasks_executed();
    client.shutdown();
    coord.shutdown();
    (done, cancelled, tasks)
}

/// The request mix for the shard-scaling mode: 8 distinct datasets so
/// the affinity router spreads work across up to 4 members (2+ datasets
/// each) instead of serializing on one member's caches.
fn workload_sharded(n: usize, count: usize, max_iters: usize) -> Vec<Request> {
    (0..count)
        .map(|i| {
            let data = DataSpec {
                n,
                seed: (i % 8) as u64,
                ..DataSpec::default()
            };
            let kind = match i % 3 {
                0 => RequestKind::Mle {
                    variant: Variant::Exact,
                    opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, max_iters),
                },
                1 => RequestKind::Predict { grid: 6 },
                _ => RequestKind::Simulate,
            };
            Request {
                data: data.into(),
                kind,
                priority: 0,
                deadline_ms: None,
            }
        })
        .collect()
}

/// Shard-scaling mode: the same request mix against a
/// [`ShardedCoordinator`] at growing shard counts.  Scale-OUT framing
/// (the paper's per-node worker pools): every shard brings its own
/// 2-worker runtime, so req/s should grow with the shard count while
/// per-request latency stays flat.
fn run_sharded(ts: usize, reqs: &[Request], clients: usize, nshards: usize) -> (f64, Vec<f64>) {
    let hw = Hardware {
        ncores: 2 * nshards,
        ts,
        policy: Policy::Lws,
        ..Hardware::default()
    };
    let coord: Arc<dyn Dispatch> = if nshards > 1 {
        Arc::new(ShardedCoordinator::new(hw, nshards))
    } else {
        Arc::new(Coordinator::new(hw))
    };
    let client = Client::from_dispatch(coord.clone(), clients);
    let t0 = Instant::now();
    let tickets: Vec<_> = reqs.iter().map(|r| client.submit(r.clone())).collect();
    let mut lats = Vec::with_capacity(tickets.len());
    for t in &tickets {
        match t.wait() {
            Completion::Done(r) => lats.push(r.wall_s),
            Completion::Cancelled => {}
            Completion::TimedOut => panic!("sharded bench request timed out (no deadlines set)"),
            Completion::Failed(e) => panic!("sharded bench request failed: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    client.shutdown();
    coord.shutdown_dispatch();
    (wall, lats)
}

fn pct(lat: &mut [f64], p: f64) -> f64 {
    lat.sort_by(f64::total_cmp);
    exageostat::testkit::percentile(lat, p)
}

fn main() {
    let quick = quick();
    let n = if quick { 100 } else { 250 };
    let count = if quick { 6 } else { 18 };
    let max_iters = if quick { 4 } else { 12 };
    let clients = 4;
    let hw = Hardware {
        ncores: 2,
        ts: 64,
        policy: Policy::Prio,
        ..Hardware::default()
    };
    let reqs = workload(n, count, max_iters);

    println!(
        "Serving throughput — {count} requests (n={n}, {max_iters} MLE iters), \
         {clients} clients, {} workers",
        hw.ncores
    );
    header(&["mode", "wall s", "req/s", "p50 s", "p95 s", "p99 s"]);

    let (seq_wall, mut seq_lat) = run_sequential(&hw, &reqs);
    let seq_rps = count as f64 / seq_wall;
    let (seq_p50, seq_p95, seq_p99) = (
        pct(&mut seq_lat, 0.50),
        pct(&mut seq_lat, 0.95),
        pct(&mut seq_lat, 0.99),
    );
    row(&[
        "per-job".into(),
        s(seq_wall),
        s2(seq_rps),
        s(seq_p50),
        s(seq_p95),
        s(seq_p99),
    ]);

    let (con_wall, mut con_lat) = run_concurrent(&hw, &reqs, clients);
    let con_rps = count as f64 / con_wall;
    let (con_p50, con_p95, con_p99) = (
        pct(&mut con_lat, 0.50),
        pct(&mut con_lat, 0.95),
        pct(&mut con_lat, 0.99),
    );
    row(&[
        "shared".into(),
        s(con_wall),
        s2(con_rps),
        s(con_p50),
        s(con_p95),
        s(con_p99),
    ]);

    let jsonl = workload_jsonl(n, count, max_iters);
    let window = 2 * clients;
    let (str_wall, mut str_lat) = run_streaming(&hw, &jsonl, clients, window);
    let str_rps = count as f64 / str_wall;
    let (str_p50, str_p95, str_p99) = (
        pct(&mut str_lat, 0.50),
        pct(&mut str_lat, 0.95),
        pct(&mut str_lat, 0.99),
    );
    row(&[
        "streaming".into(),
        s(str_wall),
        s2(str_rps),
        s(str_p50),
        s(str_p95),
        s(str_p99),
    ]);

    let (can_done, can_cancelled, can_tasks) = run_cancelling(&hw, &reqs);
    println!(
        "\ncancellation round: {can_done} completed, {can_cancelled} cancelled \
         (every 2nd ticket, mixed kinds), {can_tasks} tasks executed"
    );

    // Shard-scaling mode: 1 / 2 / 4 member coordinators, 2 workers each.
    let shard_reqs = workload_sharded(n, if quick { 12 } else { 24 }, max_iters);
    println!("\nshard scaling — {} requests, 2 workers/shard", shard_reqs.len());
    header(&["shards", "wall s", "req/s", "p50 s", "p95 s", "p99 s", "vs 1"]);
    let mut base_rps = 0.0f64;
    let mut shard_rows: Vec<String> = Vec::new();
    for &k in &[1usize, 2, 4] {
        let (wall, mut lat) = run_sharded(hw.ts, &shard_reqs, clients, k);
        let rps = shard_reqs.len() as f64 / wall.max(1e-12);
        if k == 1 {
            base_rps = rps;
        }
        let speedup = rps / base_rps.max(1e-12);
        let (p50, p95, p99) = (pct(&mut lat, 0.50), pct(&mut lat, 0.95), pct(&mut lat, 0.99));
        row(&[
            format!("{k}"),
            s(wall),
            s2(rps),
            s(p50),
            s(p95),
            s(p99),
            s2(speedup),
        ]);
        shard_rows.push(format!(
            "{{\"shards\": {k}, \"ncores_per_shard\": 2, \"req_per_s\": {rps}, \
             \"p50_s\": {p50}, \"p95_s\": {p95}, \"p99_s\": {p99}, \
             \"speedup_vs_1\": {speedup}}}"
        ));
    }
    println!(
        "shape check: req/s grows with the shard count (each shard adds a\n\
         2-worker runtime + private caches); 2 shards should clear 1.4x."
    );
    println!(
        "shape check: the shared persistent runtime should serve at >= the\n\
         sequential per-job-pool rate (cache reuse + no spawn/join per job);\n\
         here {:.2}x (streaming {:.2}x).",
        con_rps / seq_rps.max(1e-12),
        str_rps / seq_rps.max(1e-12)
    );

    let json = format!(
        "{{\n  \"bench\": \"serving_throughput\",\n  \"n\": {n},\n  \
         \"requests\": {count},\n  \"clients\": {clients},\n  \
         \"ncores\": {},\n  \"mle_max_iters\": {max_iters},\n  \
         \"shared\": {{\"wall_s\": {con_wall}, \"req_per_s\": {con_rps}, \
         \"p50_s\": {con_p50}, \"p95_s\": {con_p95}, \"p99_s\": {con_p99}}},\n  \
         \"sequential_per_job\": {{\"wall_s\": {seq_wall}, \"req_per_s\": {seq_rps}, \
         \"p50_s\": {seq_p50}, \"p95_s\": {seq_p95}, \"p99_s\": {seq_p99}}},\n  \
         \"streaming\": {{\"wall_s\": {str_wall}, \"req_per_s\": {str_rps}, \
         \"p50_s\": {str_p50}, \"p95_s\": {str_p95}, \"p99_s\": {str_p99}, \
         \"window\": {window}}},\n  \
         \"cancellation\": {{\"completed\": {can_done}, \"cancelled\": {can_cancelled}, \
         \"tasks_executed\": {can_tasks}}},\n  \
         \"shards\": [\n    {}\n  ]\n}}\n",
        hw.ncores,
        shard_rows.join(",\n    ")
    );
    let out = bench_out_path("BENCH_serving.json");
    std::fs::write(&out, &json)
        .unwrap_or_else(|e| eprintln!("cannot write {}: {e}", out.display()));
    println!("telemetry written to {}", out.display());
}
