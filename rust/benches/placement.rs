//! **Placement** — cost-model-driven task placement vs class-blind
//! scheduling on a heterogeneous worker pool (DESIGN.md §2i).
//!
//! The paper's heterogeneous results come from StarPU keeping slow
//! resources off the critical path; this machine has no accelerator, so
//! the bench simulates heterogeneity with the throttled `Slow` worker
//! class (`EXAGEOSTAT_SLOW_FACTOR`, default 4x) and measures the same
//! policy effect:
//!
//! * **blind** — one merged scheduling class (the pre-placement
//!   behaviour): any worker, including the throttled one, may pick up
//!   POTRF/TRSM and stall the whole factorization chain.
//! * **placed** — per-class queues + the HEFT placer: the slow class
//!   only receives eligible off-critical work (DCMG/GEMM/SYRK) and only
//!   when its estimated finish time wins.
//!
//! Also reports the heterogeneous DES projection (`simulate_placed`)
//! against the measured warm eval, tying the simulator's cost logic to
//! reality.  Emits BENCH_placement.json for the CI bench gate.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use exageostat::pipeline::{lower_tiled, plan, PlanKnobs, TiledSpec};
use exageostat::scheduler::des::simulate_placed;
use exageostat::scheduler::placement::{ClassSpec, Placer};
use exageostat::scheduler::pool::Policy;
use exageostat::scheduler::runtime::Runtime;
use exageostat::simulation::simulate_data_exact;
use std::sync::Arc;

fn main() {
    let quick = quick();
    let (n, ts, spec_str) = if quick {
        (400usize, 64usize, "cpu:1,slow:1")
    } else {
        (1200usize, 100usize, "cpu:3,slow:1")
    };
    let reps = if quick { 3 } else { 5 };
    let spec = ClassSpec::parse(spec_str).unwrap();
    let theta = [1.0, 0.1, 0.5];
    let kernel: Arc<dyn exageostat::covariance::CovKernel> =
        Arc::from(kernel_by_name("ugsm-s").unwrap());

    let ctx0 = ExecCtx::new(1, ts, Policy::Lws);
    let data = simulate_data_exact(
        kernel.clone(),
        &theta,
        n,
        DistanceMetric::Euclidean,
        0,
        &ctx0,
    )
    .unwrap();
    let problem = Problem {
        kernel: kernel.clone(),
        locs: Arc::new(data.locs.clone()),
        z: Arc::new(data.z.clone()),
        metric: DistanceMetric::Euclidean,
    };

    // Same worker mix in both runtimes — the slow worker is throttled in
    // both — only the scheduling differs (per-class queues + placer vs
    // one merged class).
    let warm_eval = |ctx: &ExecCtx| -> f64 {
        let mut session = EvalSession::new(&problem, Variant::Exact, ctx).unwrap();
        session.eval(&theta).unwrap(); // cold: allocate + learn costs
        time_median(reps, || {
            session.eval(&theta).unwrap();
        })
    };

    let blind_rt = Arc::new(Runtime::new_with_classes_blind(&spec, Policy::Lws));
    let blind_ctx = ExecCtx::with_runtime(blind_rt, ts, exageostat::backend::default_engine());
    let t_blind = warm_eval(&blind_ctx);

    let placed_rt = Arc::new(Runtime::new_with_classes(&spec, Policy::Lws));
    let placed_ctx = ExecCtx::with_runtime(
        placed_rt.clone(),
        ts,
        exageostat::backend::default_engine(),
    );
    let t_placed = warm_eval(&placed_ctx);

    let speedup = t_blind / t_placed;

    // Heterogeneous DES projection of the same placed plan, priced by the
    // cost model the placed runtime measured — the contract is that the
    // projection and the measurement stay within the same small multiple.
    let ir = lower_tiled(&TiledSpec {
        n,
        ts,
        band: None,
        mp_band: None,
        tlr: false,
        with_solve: true,
        with_logdet: true,
        owners: 1,
    });
    let mut pl = plan(&ir, &PlanKnobs::from_env());
    let cost = placed_rt.cost_model_by_class();
    Placer::new(&placed_rt.classes())
        .with_cost(cost.clone())
        .place(&mut pl);
    let sim = simulate_placed(&pl, &cost, &placed_rt.classes());
    let des_ratio = sim.makespan / t_placed;

    println!("Placement — warm exact eval (n={n}, ts={ts}, classes {spec_str})");
    header(&["config", "warm eval s", "speedup", "des proj s", "des ratio"]);
    row(&[
        "blind".into(),
        s(t_blind),
        s2(1.0),
        "-".into(),
        "-".into(),
    ]);
    row(&[
        "placed".into(),
        s(t_placed),
        s2(speedup),
        s(sim.makespan),
        s2(des_ratio),
    ]);

    let stats = placed_rt.class_stats();
    for c in &stats {
        println!(
            "  class {:<6} x{}: {} placed, {} executed, {} steals",
            c.class.name(),
            c.workers,
            c.tasks_placed,
            c.tasks_executed,
            c.steals
        );
    }

    let json = format!(
        "{{\n  \"placement\": {{\n    \"n\": {n},\n    \"ts\": {ts},\n    \
         \"classes\": \"{spec_str}\",\n    \"blind_warm_eval_s\": {t_blind},\n    \
         \"placed_warm_eval_s\": {t_placed},\n    \"speedup_vs_blind\": {speedup},\n    \
         \"des_makespan_s\": {},\n    \"des_ratio\": {des_ratio}\n  }}\n}}\n",
        sim.makespan
    );
    let path = bench_out_path("BENCH_placement.json");
    std::fs::write(&path, json).expect("write BENCH_placement.json");
    println!("wrote {}", path.display());
}
