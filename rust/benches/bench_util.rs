#![allow(dead_code)]
//! Shared mini bench harness (the offline substitute for criterion — see
//! DESIGN.md substitution table): warmup + median-of-k wall-clock timing
//! and aligned table output.

use std::time::Instant;

/// Median of `k` timed runs (after one warmup) in seconds.
pub fn time_median(k: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut samples: Vec<f64> = (0..k.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2]
}

/// Print a header line for a table.
pub fn header(cols: &[&str]) {
    let row: Vec<String> = cols.iter().map(|c| format!("{c:>12}")).collect();
    println!("{}", row.join(" "));
    println!("{}", "-".repeat(13 * cols.len()));
}

/// Print one row of formatted cells.
pub fn row(cells: &[String]) {
    let row: Vec<String> = cells.iter().map(|c| format!("{c:>12}")).collect();
    println!("{}", row.join(" "));
}

/// Resolve the output path for a bench's JSON telemetry under the
/// `BENCH_OUT` override.  A value ending in `.json` names the file
/// directly (single-bench back-compat); anything else is a directory the
/// bench writes its default-named file into — so CI exports one
/// directory for the whole suite and the artifact glob no longer
/// depends on cargo's bench working directory.
pub fn bench_out_path(default_name: &str) -> std::path::PathBuf {
    match std::env::var("BENCH_OUT") {
        Ok(v) if v.ends_with(".json") => std::path::PathBuf::from(v),
        Ok(v) => std::path::Path::new(&v).join(default_name),
        Err(_) => std::path::PathBuf::from(default_name),
    }
}

/// `--quick` flag: benches honor it to shrink problem sizes under CI.
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok()
}

/// Fixed-format helpers.
pub fn s(v: f64) -> String {
    format!("{v:.4}")
}
pub fn s2(v: f64) -> String {
    format!("{v:.2}")
}
