//! Ablations the paper motivates but does not tabulate:
//!
//! 1. **Computation variants** (Fig 1): exact vs DST(band) vs TLR(tol) vs
//!    MP(band) — evaluation time, likelihood error vs exact, and (TLR)
//!    storage footprint.
//! 2. **Scheduler policies** (§III-B, STARPU_SCHED): eager / prio / lws /
//!    random on the tiled Cholesky DAG.
//! 3. **Morton ordering** on/off for TLR compressibility (the design
//!    choice DESIGN.md §4 calls out).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, morton_perm, DistanceMetric};
use exageostat::likelihood::{self, tlr, ExecCtx, Problem, Variant};
use exageostat::linalg::cholesky::{new_fail_flag, submit_tiled_potrf, TileHandles};
use exageostat::linalg::lowrank::LrOpts;
use exageostat::linalg::tile::TileMatrix;
use exageostat::scheduler::pool::{self, Policy};
use exageostat::scheduler::TaskGraph;
use exageostat::simulation::simulate_data_exact;
use std::sync::Arc;

fn main() {
    let quick = quick();
    let n = if quick { 400 } else { 1024 };
    let ts = 64;
    let theta = [1.0, 0.1, 0.5];
    let kernel: Arc<dyn exageostat::covariance::CovKernel> =
        Arc::from(kernel_by_name("ugsm-s").unwrap());
    let ctx = ExecCtx::new(2, ts, Policy::Prio);
    let data =
        simulate_data_exact(kernel.clone(), &theta, n, DistanceMetric::Euclidean, 0, &ctx).unwrap();
    let problem = Problem {
        kernel: kernel.clone(),
        locs: Arc::new(data.locs.clone()),
        z: Arc::new(data.z.clone()),
        metric: DistanceMetric::Euclidean,
    };

    // ---- 1. variants -----------------------------------------------------
    println!("ablation 1 — computation variants (n={n}, ts={ts})");
    header(&["variant", "time (s)", "|ll err|"]);
    let exact = likelihood::loglik(&problem, &theta, Variant::Exact, &ctx).unwrap();
    let variants: Vec<(String, Variant)> = vec![
        ("exact".into(), Variant::Exact),
        ("dst b=1".into(), Variant::Dst { band: 1 }),
        ("dst b=2".into(), Variant::Dst { band: 2 }),
        ("dst b=4".into(), Variant::Dst { band: 4 }),
        ("mp b=0".into(), Variant::Mp { band: 0 }),
        ("mp b=2".into(), Variant::Mp { band: 2 }),
        (
            "tlr 1e-3".into(),
            Variant::Tlr {
                tol: 1e-3,
                max_rank: usize::MAX,
            },
        ),
        (
            "tlr 1e-7".into(),
            Variant::Tlr {
                tol: 1e-7,
                max_rank: usize::MAX,
            },
        ),
    ];
    for (name, v) in variants {
        // An over-aggressive DST band can lose positive definiteness —
        // a real failure mode of the approximation (the paper: "the user
        // should expect losing some accuracy with more zero tiles").
        match likelihood::loglik(&problem, &theta, v, &ctx) {
            Ok(r) => {
                let t = time_median(if quick { 1 } else { 3 }, || {
                    let _ = likelihood::loglik(&problem, &theta, v, &ctx);
                });
                row(&[
                    name,
                    s(t),
                    format!("{:.3e}", (r.loglik - exact.loglik).abs()),
                ]);
            }
            Err(_) => row(&[name, "—".into(), "not SPD".into()]),
        }
    }

    // ---- 2. scheduler policies -------------------------------------------
    println!("\nablation 2 — scheduler policy on the tiled Cholesky DAG (n={n}, ts={ts})");
    header(&["policy", "wall (s)", "tasks", "eff %"]);
    for policy in [Policy::Eager, Policy::Prio, Policy::Lws, Policy::Random] {
        let t = time_median(if quick { 1 } else { 3 }, || {
            let a = TileMatrix::zeros(n, ts);
            let mut g = TaskGraph::new();
            let hs = TileHandles::register(&mut g, a.nt());
            likelihood::exact::submit_generation(&mut g, &a, &hs, &problem, &theta, None);
            let fail = new_fail_flag();
            submit_tiled_potrf(&mut g, &a, &hs, None, &fail);
            pool::run(&mut g, 4, policy);
        });
        // one instrumented run for task count / efficiency
        let a = TileMatrix::zeros(n, ts);
        let mut g = TaskGraph::new();
        let hs = TileHandles::register(&mut g, a.nt());
        likelihood::exact::submit_generation(&mut g, &a, &hs, &problem, &theta, None);
        let fail = new_fail_flag();
        submit_tiled_potrf(&mut g, &a, &hs, None, &fail);
        let prof = pool::run(&mut g, 4, policy);
        row(&[
            format!("{policy:?}"),
            s(t),
            format!("{}", prof.total_tasks()),
            s2(100.0 * prof.efficiency()),
        ]);
    }

    // ---- 3. Morton ordering for TLR ---------------------------------------
    println!("\nablation 3 — Morton ordering and TLR storage (n={n}, ts={ts}, tol=1e-7)");
    header(&["ordering", "storage", "dense", "pct"]);
    let opts = LrOpts {
        tol: 1e-7,
        max_rank: usize::MAX,
    };
    for (name, order) in [("original", false), ("morton", true)] {
        let locs: Vec<_> = if order {
            morton_perm(&problem.locs)
                .iter()
                .map(|&i| problem.locs[i])
                .collect()
        } else {
            problem.locs.to_vec()
        };
        let p2 = Problem {
            kernel: kernel.clone(),
            locs: Arc::new(locs),
            z: problem.z.clone(),
            metric: problem.metric,
        };
        let a = tlr::generate(&p2, &theta, opts, ts);
        row(&[
            name.to_string(),
            format!("{}", a.storage_len()),
            format!("{}", a.dense_storage_len()),
            s2(100.0 * a.storage_len() as f64 / a.dense_storage_len() as f64),
        ]);
    }
    println!("\nshape check: morton < original storage; prio ~ lws <= eager <= random wall.");
}
