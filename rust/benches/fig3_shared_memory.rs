//! **Fig 3** — parallel execution performance on shared memory: execution
//! time per MLE iteration vs number of cores (1..16) for tile sizes
//! {100, 160, 320, 560} and n in {400, 900, 1600}.
//!
//! Testbed note (DESIGN.md "Hardware adaptation"): this machine exposes a
//! single physical core, so multi-core *wall-clock* cannot show real
//! speedup.  We therefore report, per the substitution rule:
//!   (1) measured single-worker time per iteration (real), and
//!   (2) the DES-projected time on k cores, driven by the *measured*
//!       per-task-kind cost model of the same run — reproducing the shape
//!       of Fig 3 (more cores help until tiles run out; small tiles pay
//!       scheduling overhead, huge tiles starve parallelism).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{exact, ExecCtx, Problem};
use exageostat::linalg::cholesky::{new_fail_flag, submit_tiled_potrf, TileHandles};
use exageostat::linalg::tile::TileMatrix;
use exageostat::scheduler::des::{cpu_machine, simulate, CommModel};
use exageostat::scheduler::pool::Policy;
use exageostat::scheduler::TaskGraph;
use exageostat::simulation::simulate_data_exact;
use std::sync::Arc;

fn main() {
    let quick = quick();
    let sizes: &[usize] = if quick { &[400, 900] } else { &[400, 900, 1600] };
    let tile_sizes: &[usize] = if quick { &[100, 320] } else { &[100, 160, 320, 560] };
    let cores: &[usize] = &[1, 2, 4, 8, 16];
    let theta = [1.0, 0.1, 0.5];
    let kernel: Arc<dyn exageostat::covariance::CovKernel> =
        Arc::from(kernel_by_name("ugsm-s").unwrap());

    println!("Fig 3 — time per iteration (s): measured 1-core + DES projection to k cores");
    for &n in sizes {
        let ctx0 = ExecCtx::new(1, 320, Policy::Prio);
        let data = simulate_data_exact(
            kernel.clone(),
            &theta,
            n,
            DistanceMetric::Euclidean,
            0,
            &ctx0,
        )
        .unwrap();
        let problem = Problem {
            kernel: kernel.clone(),
            locs: Arc::new(data.locs.clone()),
            z: Arc::new(data.z.clone()),
            metric: DistanceMetric::Euclidean,
        };
        println!("\nn = {n}");
        header(&["ts", "meas 1c", "des 1c", "des 2c", "des 4c", "des 8c", "des 16c"]);
        for &ts in tile_sizes {
            // Measured: one full likelihood evaluation, single worker.
            let ctx = ExecCtx::new(1, ts, Policy::Prio);
            let t_meas = time_median(if quick { 1 } else { 3 }, || {
                let _ = exageostat::likelihood::loglik(
                    &problem,
                    &theta,
                    exageostat::likelihood::Variant::Exact,
                    &ctx,
                )
                .unwrap();
            });
            // Cost model from a profiled serial run of the same graph.
            let dim = problem.dim();
            let a = TileMatrix::zeros(dim, ts);
            let mut g = TaskGraph::new();
            let hs = TileHandles::register(&mut g, a.nt());
            exact::submit_generation(&mut g, &a, &hs, &problem, &theta, None);
            let fail = new_fail_flag();
            submit_tiled_potrf(&mut g, &a, &hs, None, &fail);
            let prof = g.run_serial();
            let cm = prof.cost_model();
            // Replay the DAG (structure only) on k simulated cores.
            let mut cells = vec![format!("{ts}"), s(t_meas)];
            for &k in cores {
                let machine = cpu_machine(k);
                // rebuild the graph (run_serial consumed closures, but the
                // structure is what the DES needs — rebuild cheaply)
                let a2 = TileMatrix::zeros(dim, ts);
                let mut g2 = TaskGraph::new();
                let hs2 = TileHandles::register(&mut g2, a2.nt());
                exact::submit_generation(&mut g2, &a2, &hs2, &problem, &theta, None);
                let fail2 = new_fail_flag();
                submit_tiled_potrf(&mut g2, &a2, &hs2, None, &fail2);
                let r = simulate(&g2, &cm, &machine, &CommModel::zero(), None);
                cells.push(s(r.makespan));
            }
            row(&cells);
        }
    }
    println!("\nshape check (paper): ts=100 best on small n; larger ts starves parallelism.");
}
