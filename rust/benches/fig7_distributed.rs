//! **Fig 7** — strong scaling on a distributed-memory system: time per
//! iteration on 2x2, 4x4, 8x8 and 16x16 node grids (paper: Shaheen II
//! Cray XC40, 31 cores/node, ts=960, n up to 250k, STARPU_SCHED=eager).
//!
//! No cluster on this testbed, so per DESIGN.md the node grid is modeled
//! in the DES: 2-D block-cyclic tile ownership (the placement constraint
//! the paper's runtime uses), measured per-task cost models, and an
//! Aries-like network model (1.5 us latency, 10 GB/s per node).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{exact, ExecCtx, Problem};
use exageostat::linalg::cholesky::{new_fail_flag, submit_tiled_potrf, TileHandles};
use exageostat::linalg::tile::TileMatrix;
use exageostat::pipeline::shard::ShardGrid;
use exageostat::scheduler::des::{block_cyclic_owner, cluster_machine, simulate, CommModel};
use exageostat::scheduler::pool::Policy;
use exageostat::scheduler::TaskGraph;
use exageostat::simulation::simulate_data_exact;
use std::sync::Arc;

fn main() {
    let quick = quick();
    let sizes: &[usize] = if quick {
        &[3600, 6400]
    } else {
        &[3600, 10000, 22500]
    };
    let grids: &[(usize, usize)] = &[(2, 2), (4, 4), (8, 8), (16, 16)];
    let cores_per_node = 8; // scaled from the paper's 31 to keep the DES fast
    let theta = [1.0, 0.1, 0.5];
    let kernel: Arc<dyn exageostat::covariance::CovKernel> =
        Arc::from(kernel_by_name("ugsm-s").unwrap());
    let ctx = ExecCtx::new(1, 320, Policy::Eager); // paper: STARPU_SCHED=eager
    let comm = CommModel {
        latency: 1.5e-6,
        bandwidth: 10e9,
    };

    println!("Fig 7 — DES-projected time per iteration (s) on p x q node grids");
    header(&["n", "2x2", "4x4", "8x8", "16x16"]);
    for &n in sizes {
        let ts = (n / 16).clamp(160, 640);
        let data =
            simulate_data_exact(kernel.clone(), &theta, n, DistanceMetric::Euclidean, 0, &ctx)
                .unwrap();
        let problem = Problem {
            kernel: kernel.clone(),
            locs: Arc::new(data.locs),
            z: Arc::new(data.z),
            metric: DistanceMetric::Euclidean,
        };
        let nt = problem.dim().div_ceil(ts);
        let build = || -> (TileMatrix, TaskGraph, Vec<(usize, usize)>) {
            let a = TileMatrix::zeros(problem.dim(), ts);
            let mut g = TaskGraph::new();
            let hs = TileHandles::register(&mut g, a.nt());
            exact::submit_generation(&mut g, &a, &hs, &problem, &theta, None);
            let fail = new_fail_flag();
            submit_tiled_potrf(&mut g, &a, &hs, None, &fail);
            // handle id -> (tile_i, tile_j): TileHandles registers the
            // lower triangle in row-major tri order starting at handle 0.
            let mut coords = Vec::new();
            for i in 0..nt {
                for j in 0..=i {
                    coords.push((i, j));
                }
            }
            (a, g, coords)
        };
        let (_a, mut gserial, _) = build();
        let cm = gserial.run_serial().cost_model();

        let mut cells = vec![format!("{n}")];
        for &(p, q) in grids {
            let (_a2, g2, coords) = build();
            let machine = cluster_machine(p, q, cores_per_node);
            // 2-D block-cyclic ownership, exactly the paper's distribution
            // (the same ShardGrid the live sharding pass uses).
            let owner = block_cyclic_owner(ShardGrid::new(p, q), Arc::new(coords));
            let r = simulate(&g2, &cm, &machine, &comm, Some(&owner));
            cells.push(s(r.makespan));
        }
        row(&cells);
    }
    println!(
        "\nshape check (paper): strong scaling up to 64 nodes; small n stops scaling\n\
         early (communication + too few tiles per node), large n keeps scaling."
    );
}
