//! **Table V** — average execution time per iteration and average number
//! of iterations to reach the tolerance, for the nine (beta x nu)
//! scenarios, across the three estimators.
//!
//! Paper protocol: n = 1600, 100 replicates, abs tol 1e-5, starts at the
//! lower bounds.  Scaled defaults here: n = 400, 3 replicates
//! (`BENCH_FULL=1` for n=1600).
//!
//! Besides the table, this bench emits machine-readable MLE-iteration
//! telemetry to `BENCH_mle_iter.json` (override the path with
//! `BENCH_OUT`): per-variant median time/iteration and iteration counts,
//! plus the warm-vs-cold evaluation speedup of the `EvalSession` hot loop
//! (distance-tile cache + symmetric generation + zero warm allocations).
//! `BENCH_N` overrides the problem size of the session measurement (e.g.
//! `BENCH_N=6400` for the paper-scale regime).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::api::{ExaGeoStat, Hardware, MleOptions, MleResult};
use exageostat::baselines::{fieldslike_mle, georlike_mle};
use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{self, EvalSession, Problem, Variant};
use exageostat::scheduler::pool::Policy;
use std::sync::Arc;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let quick = quick();
    let n = if full { 1600 } else { 400 };
    let reps = if full {
        10
    } else if quick {
        1
    } else {
        3
    };
    let tol = 1e-5;
    let betas = [0.03, 0.1, 0.3];
    let nus = [0.5, 1.0, 2.0];

    let ts = 100;
    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ts,
        policy: Policy::Prio,
        ..Hardware::default()
    });

    println!("Table V — avg time/iter (s) and avg #iters; n={n}, reps={reps}, tol={tol}");
    header(&[
        "beta", "nu", "t geor", "t fields", "t exa", "it geor", "it field", "it exa",
    ]);
    for &nu in &nus {
        for &beta in &betas {
            let theta = [1.0, beta, nu];
            let (mut tg, mut tf, mut te) = (0.0, 0.0, 0.0);
            let (mut ig, mut iff, mut ie) = (0usize, 0usize, 0usize);
            for rep in 0..reps {
                let data = exa
                    .simulate_data_exact("ugsm-s", &theta, "euclidean", n, 100 + rep as u64)
                    .unwrap();
                let g = georlike_mle(
                    &data,
                    DistanceMetric::Euclidean,
                    &[0.001; 3],
                    &[5.0; 3],
                    tol,
                    500,
                )
                .unwrap();
                tg += g.time_per_iter;
                ig += g.iters;
                let f = fieldslike_mle(
                    &data,
                    DistanceMetric::Euclidean,
                    nu,
                    &[0.001; 2],
                    &[5.0; 2],
                    tol,
                    500,
                )
                .unwrap();
                tf += f.time_per_iter;
                iff += f.iters;
                let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], tol, 0);
                let e = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();
                te += e.time_per_iter;
                ie += e.iters;
            }
            let rf = reps as f64;
            row(&[
                format!("{beta}"),
                format!("{nu}"),
                s(tg / rf),
                s(tf / rf),
                s(te / rf),
                format!("{}", ig / reps),
                format!("{}", iff / reps),
                format!("{}", ie / reps),
            ]);
        }
    }
    println!(
        "\nshape check (paper Table V): exageostat time/iter ~12x below geor-like and ~7x\n\
         below fields-like; exageostat takes MORE iterations (BOBYQA explores more) but\n\
         far less total time; iterations grow with nu for exageostat."
    );

    // -----------------------------------------------------------------
    // Machine-readable MLE-iteration telemetry (BENCH_mle_iter.json)
    // -----------------------------------------------------------------
    let n_sess: usize = std::env::var("BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(n);
    let theta = [1.0, 0.1, 0.5];
    let data = exa
        .simulate_data_exact("ugsm-s", &theta, "euclidean", n_sess, 7)
        .unwrap();

    // Per-variant MLE runs through the session-backed api::mle route.
    let max_iters = if quick { 25 } else { 200 };
    let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], tol, max_iters);
    let mut variant_rows: Vec<(String, MleResult)> = Vec::new();
    let exact = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();
    variant_rows.push(("exact".into(), exact));
    let dst = exa.dst_mle(&data, "ugsm-s", "euclidean", &opt, 2).unwrap();
    variant_rows.push(("dst_band2".into(), dst));
    let tlr = exa
        .tlr_mle(&data, "ugsm-s", "euclidean", &opt, 1e-7, usize::MAX)
        .unwrap();
    variant_rows.push(("tlr_tol1e-7".into(), tlr));
    let mp = exa.mp_mle(&data, "ugsm-s", "euclidean", &opt, 1).unwrap();
    variant_rows.push(("mp_band1".into(), mp));

    // Warm-vs-cold single-evaluation speedup: the direct measurement of
    // what the session layer buys per optimizer iteration.
    let problem = Problem {
        kernel: kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(data.locs.clone()),
        z: Arc::new(data.z.clone()),
        metric: DistanceMetric::Euclidean,
    };
    let ctx = exa.ctx();
    let k = if quick { 2 } else { 5 };
    let cold = time_median(k, || {
        likelihood::loglik(&problem, &theta, Variant::Exact, &ctx).unwrap();
    });
    let mut session = EvalSession::new(&problem, Variant::Exact, &ctx).unwrap();
    session.eval(&theta).unwrap(); // warm the distance cache + workspace
    let warm = time_median(k, || {
        session.eval(&theta).unwrap();
    });
    let speedup = cold / warm;
    println!(
        "\nEvalSession exact eval at n={n_sess}: cold {:.4}s, warm {:.4}s ({speedup:.2}x)",
        cold, warm
    );

    // f64 -> JSON number; non-finite values (e.g. -inf loglik when every
    // probe was non-SPD) become null so the document stays parseable.
    let jnum = |v: f64| -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "null".into()
        }
    };
    let variants_json: Vec<String> = variant_rows
        .iter()
        .map(|(name, r)| {
            format!(
                "    {{\"variant\": \"{name}\", \"time_per_iter_s\": {}, \
                 \"iters\": {}, \"loglik\": {}}}",
                jnum(r.time_per_iter),
                r.iters,
                jnum(r.loglik)
            )
        })
        .collect();
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"table5_time_per_iter\",\n");
    json.push_str(&format!("  \"n\": {n_sess},\n  \"ts\": {ts},\n  \"tol\": {tol},\n"));
    json.push_str(&format!("  \"variants\": [\n{}\n  ],\n", variants_json.join(",\n")));
    json.push_str("  \"session\": {\n    \"variant\": \"exact\",\n");
    json.push_str(&format!(
        "    \"cold_eval_s\": {},\n    \"warm_eval_s\": {},\n    \
         \"speedup_warm_vs_cold\": {}\n",
        jnum(cold),
        jnum(warm),
        jnum(speedup)
    ));
    json.push_str("  }\n}\n");
    let out = bench_out_path("BENCH_mle_iter.json");
    std::fs::write(&out, &json)
        .unwrap_or_else(|e| eprintln!("cannot write {}: {e}", out.display()));
    println!("telemetry written to {}", out.display());
    exa.finalize();
}
