//! **Table V** — average execution time per iteration and average number
//! of iterations to reach the tolerance, for the nine (beta x nu)
//! scenarios, across the three estimators.
//!
//! Paper protocol: n = 1600, 100 replicates, abs tol 1e-5, starts at the
//! lower bounds.  Scaled defaults here: n = 400, 3 replicates
//! (`BENCH_FULL=1` for n=1600).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::baselines::{fieldslike_mle, georlike_mle};
use exageostat::covariance::DistanceMetric;
use exageostat::scheduler::pool::Policy;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let quick = quick();
    let n = if full { 1600 } else { 400 };
    let reps = if full {
        10
    } else if quick {
        1
    } else {
        3
    };
    let tol = 1e-5;
    let betas = [0.03, 0.1, 0.3];
    let nus = [0.5, 1.0, 2.0];

    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ts: 100,
        policy: Policy::Prio,
        ..Hardware::default()
    });

    println!("Table V — avg time/iter (s) and avg #iters; n={n}, reps={reps}, tol={tol}");
    header(&[
        "beta", "nu", "t geor", "t fields", "t exa", "it geor", "it field", "it exa",
    ]);
    for &nu in &nus {
        for &beta in &betas {
            let theta = [1.0, beta, nu];
            let (mut tg, mut tf, mut te) = (0.0, 0.0, 0.0);
            let (mut ig, mut iff, mut ie) = (0usize, 0usize, 0usize);
            for rep in 0..reps {
                let data = exa
                    .simulate_data_exact("ugsm-s", &theta, "euclidean", n, 100 + rep as u64)
                    .unwrap();
                let g = georlike_mle(
                    &data,
                    DistanceMetric::Euclidean,
                    &[0.001; 3],
                    &[5.0; 3],
                    tol,
                    500,
                )
                .unwrap();
                tg += g.time_per_iter;
                ig += g.iters;
                let f = fieldslike_mle(
                    &data,
                    DistanceMetric::Euclidean,
                    nu,
                    &[0.001; 2],
                    &[5.0; 2],
                    tol,
                    500,
                )
                .unwrap();
                tf += f.time_per_iter;
                iff += f.iters;
                let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], tol, 0);
                let e = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();
                te += e.time_per_iter;
                ie += e.iters;
            }
            let rf = reps as f64;
            row(&[
                format!("{beta}"),
                format!("{nu}"),
                s(tg / rf),
                s(tf / rf),
                s(te / rf),
                format!("{}", ig / reps),
                format!("{}", iff / reps),
                format!("{}", ie / reps),
            ]);
        }
    }
    println!(
        "\nshape check (paper Table V): exageostat time/iter ~12x below geor-like and ~7x\n\
         below fields-like; exageostat takes MORE iterations (BOBYQA explores more) but\n\
         far less total time; iterations grow with nu for exageostat."
    );
    exa.finalize();
}
