//! **Fig 6** — execution time per iteration with GPU accelerators: 1, 2
//! and 4 GPUs vs a 28-core CPU run, over growing n.
//!
//! The testbed has no GPU (paper: 8x NVIDIA K80 + dual 14-core Broadwell),
//! so per DESIGN.md this is a calibrated simulation: the task DAG and the
//! per-kind CPU cost model are *measured*, the accelerator model (speed
//! factor + PCIe-like transfer cost) replays the same DAG in the
//! discrete-event simulator.  The K80 speed factor uses the dgemm
//! throughput ratio (K80 ~1.9 TF/s fp64 peak vs ~30 GF/s per Broadwell
//! core => ~40x per-task on gemm-class kernels, conservatively 25x
//! end-to-end), PCIe latency 10 us, bandwidth 12 GB/s.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{exact, ExecCtx, Problem};
use exageostat::linalg::cholesky::{new_fail_flag, submit_tiled_potrf, TileHandles};
use exageostat::linalg::tile::TileMatrix;
use exageostat::scheduler::des::{gpu_machine, simulate, CommModel};
use exageostat::scheduler::pool::Policy;
use exageostat::scheduler::TaskGraph;
use exageostat::simulation::simulate_data_exact;
use std::sync::Arc;

const GPU_SPEED: f64 = 25.0;

fn main() {
    let quick = quick();
    let sizes: &[usize] = if quick {
        &[1600, 3600]
    } else {
        &[1600, 3600, 6400, 10000]
    };
    let ts = 960usize.min(640); // paper uses ts=960 on GPU; scaled with n here
    let theta = [1.0, 0.1, 0.5];
    let kernel: Arc<dyn exageostat::covariance::CovKernel> =
        Arc::from(kernel_by_name("ugsm-s").unwrap());
    let ctx = ExecCtx::new(1, 320, Policy::Prio);
    let comm = CommModel {
        latency: 10e-6,
        bandwidth: 12e9,
    };

    println!("Fig 6 — DES-projected time per iteration (s); measured CPU cost models");
    header(&["n", "cpu 28c", "1 gpu", "2 gpus", "4 gpus"]);
    for &n in sizes {
        let data =
            simulate_data_exact(kernel.clone(), &theta, n, DistanceMetric::Euclidean, 0, &ctx)
                .unwrap();
        let problem = Problem {
            kernel: kernel.clone(),
            locs: Arc::new(data.locs),
            z: Arc::new(data.z),
            metric: DistanceMetric::Euclidean,
        };
        let ts_n = ts.min(n / 4).max(160);
        // profile the real DAG serially once for the cost model
        let build = |p: &Problem| -> (TileMatrix, TaskGraph) {
            let a = TileMatrix::zeros(p.dim(), ts_n);
            let mut g = TaskGraph::new();
            let hs = TileHandles::register(&mut g, a.nt());
            exact::submit_generation(&mut g, &a, &hs, p, &theta, None);
            let fail = new_fail_flag();
            submit_tiled_potrf(&mut g, &a, &hs, None, &fail);
            (a, g)
        };
        let (_a, mut g) = build(&problem);
        let cm = g.run_serial().cost_model();
        let (_a2, g2) = build(&problem);

        let mut cells = vec![format!("{n}")];
        // 28-core CPU reference (the paper's "28-core no-GPU" curve)
        let cpu = simulate(&g2, &cm, &exageostat::scheduler::des::cpu_machine(28), &CommModel::zero(), None);
        cells.push(s(cpu.makespan));
        for &ngpu in &[1usize, 2, 4] {
            let machine = gpu_machine(26, ngpu, GPU_SPEED);
            let r = simulate(&g2, &cm, &machine, &comm, None);
            cells.push(s(r.makespan));
        }
        row(&cells);
    }
    println!(
        "\nshape check (paper): GPUs dominate the 28-core CPU curve; speedup grows with n\n\
         (bigger tiles amortize transfers) and scales with the number of GPUs."
    );
}
