//! **Fault tolerance** — warm exact eval under the seeded fault injector
//! (DESIGN.md §2j) at injection rates {0, 1%, 5%} with task retry armed.
//!
//! Two walls, both CI-gated (`ci/bench_baseline.json`):
//!
//! * **zero-cost when idle** — the injector hooks sit on every task
//!   boundary and every spill I/O, so the fault-free path must not pay
//!   for them.  `overhead_ratio` compares an *armed-with-zero-rates*
//!   plan (hooks fully live, nothing ever fires) against the disarmed
//!   fast path (one relaxed atomic load), measured back-to-back in the
//!   same process so runner jitter mostly cancels.
//! * **usable when firing** — `recovered_warm_eval_s` is the warm eval
//!   at a 5% per-task panic rate with a retry budget of 4: recovery has
//!   to keep the eval in the same order of magnitude, not just
//!   eventually correct.
//!
//! The bench also asserts the recovery contract itself: every faulted
//! eval must return the **bit-identical** log-likelihood of the clean
//! run (injection fires at task entry; a retried task re-executes from
//! untouched inputs).  Emits BENCH_faults.json.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use exageostat::scheduler::faults::{
    faults_injected, set_fault_plan, set_task_retry_override, tasks_retried, FaultPlan,
};
use exageostat::scheduler::pool::Policy;
use exageostat::simulation::simulate_data_exact;
use std::sync::Arc;

const RETRIES: usize = 4;

fn main() {
    let quick = quick();
    let (n, ts) = if quick { (240usize, 64usize) } else { (1200usize, 100usize) };
    let reps = if quick { 5 } else { 7 };
    let theta = [1.0, 0.1, 0.5];
    let kernel: Arc<dyn exageostat::covariance::CovKernel> =
        Arc::from(kernel_by_name("ugsm-s").unwrap());

    let ctx = ExecCtx::new(2, ts, Policy::Lws);
    let data = simulate_data_exact(
        kernel.clone(),
        &theta,
        n,
        DistanceMetric::Euclidean,
        0,
        &ctx,
    )
    .unwrap();
    let problem = Problem {
        kernel,
        locs: Arc::new(data.locs),
        z: Arc::new(data.z),
        metric: DistanceMetric::Euclidean,
    };

    set_fault_plan(None);
    set_task_retry_override(Some(RETRIES));
    let mut session = EvalSession::new(&problem, Variant::Exact, &ctx).unwrap();
    let clean = session.eval(&theta).unwrap().loglik; // cold: allocate workspaces

    let plan = |rate: f64| FaultPlan {
        panic_rate: rate,
        io_rate: rate, // inert on the resident path; drawn by spill runs
        stall_rate: rate,
        stall_ms: 1,
        seed: 42,
    };
    let mut timed_eval = |armed: Option<FaultPlan>| -> (f64, u64, u64) {
        set_fault_plan(armed);
        let (f0, r0) = (faults_injected(), tasks_retried());
        let t = time_median(reps, || {
            let ll = session.eval(&theta).unwrap().loglik;
            assert_eq!(
                ll.to_bits(),
                clean.to_bits(),
                "recovered eval must be bit-identical to the clean run"
            );
        });
        set_fault_plan(None);
        (t, faults_injected() - f0, tasks_retried() - r0)
    };

    let (t_disarmed, _, _) = timed_eval(None);
    let (t_armed_zero, _, _) = timed_eval(Some(plan(0.0)));
    let (t_1pct, inj_1, ret_1) = timed_eval(Some(plan(0.01)));
    let (t_5pct, inj_5, ret_5) = timed_eval(Some(plan(0.05)));
    set_task_retry_override(None);
    let overhead_ratio = t_armed_zero / t_disarmed;

    println!("Faults — warm exact eval under injection (n={n}, ts={ts}, retries {RETRIES})");
    header(&["rate", "warm eval s", "vs clean", "injected", "retried"]);
    row(&["off".into(), s(t_disarmed), s2(1.0), "0".into(), "0".into()]);
    row(&[
        "0%".into(),
        s(t_armed_zero),
        s2(overhead_ratio),
        "0".into(),
        "0".into(),
    ]);
    row(&[
        "1%".into(),
        s(t_1pct),
        s2(t_1pct / t_disarmed),
        inj_1.to_string(),
        ret_1.to_string(),
    ]);
    row(&[
        "5%".into(),
        s(t_5pct),
        s2(t_5pct / t_disarmed),
        inj_5.to_string(),
        ret_5.to_string(),
    ]);

    let json = format!(
        "{{\n  \"faults\": {{\n    \"n\": {n},\n    \"ts\": {ts},\n    \
         \"retries\": {RETRIES},\n    \"disarmed_warm_eval_s\": {t_disarmed},\n    \
         \"armed_zero_warm_eval_s\": {t_armed_zero},\n    \
         \"overhead_ratio\": {overhead_ratio},\n    \
         \"recovered_warm_eval_s\": {t_5pct},\n    \"rates\": [\n      \
         {{ \"rate\": 0.01, \"warm_eval_s\": {t_1pct}, \"faults_injected\": {inj_1}, \
         \"tasks_retried\": {ret_1} }},\n      \
         {{ \"rate\": 0.05, \"warm_eval_s\": {t_5pct}, \"faults_injected\": {inj_5}, \
         \"tasks_retried\": {ret_5} }}\n    ]\n  }}\n}}\n"
    );
    let path = bench_out_path("BENCH_faults.json");
    std::fs::write(&path, json).expect("write BENCH_faults.json");
    println!("wrote {}", path.display());
}
