//! **SST workload scaling under a memory budget** — fit the synthetic
//! Agulhas SST day (DESIGN.md §5) at growing n, fully resident vs under
//! an out-of-core tile budget vs mixed-precision, and report warm-eval
//! time plus spill telemetry.
//!
//! This is the bench behind two regression gates
//! (`ci/bench_baseline.json`):
//!  * `spill.resident_warm_eval_s` — the resident fast path must stay
//!    flat now that the spill branch sits on it (tight 5% band);
//!  * `spill.budget_warm_eval_s` — the budgeted serial sweep must stay
//!    usable (absolute ceiling), not just correct.
//!
//! Emits `BENCH_sst_scaling.json` (path override: `BENCH_OUT`).  Quick
//! mode (`BENCH_QUICK=1` / `--quick`) shrinks n; `BENCH_FULL=1` grows
//! it toward the paper-shaped grid.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::data::sst::{ols_linear_mean, stream_days, SstConfig};
use exageostat::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use exageostat::scheduler::pool::Policy;
use exageostat::testkit::{tile_prefetches, tile_spill_reads, tile_spill_writes};
use std::sync::Arc;

/// Dense lower-triangle footprint of the all-f64 workspace, in bytes —
/// what a resident fit of size n must hold, and the yardstick the
/// budget is set against.
fn dense_lower_bytes(n: usize, ts: usize) -> usize {
    let nt = n.div_ceil(ts);
    let dim = |t: usize| if t + 1 == nt { n - t * ts } else { ts };
    let mut total = 0;
    for i in 0..nt {
        for j in 0..=i {
            total += dim(i) * dim(j) * 8;
        }
    }
    total
}

struct BenchRow {
    n: usize,
    variant: &'static str,
    mode: String,
    warm_s: f64,
    peak_bytes: Option<usize>,
    budget_bytes: Option<usize>,
    spill_writes: u64,
    spill_reads: u64,
    prefetches: u64,
}

/// Warm-eval one (variant, budget) cell through the session layer and
/// collect spill-counter deltas.  Counters are process-global, so this
/// bench (like `rust/tests/spill.rs`) runs its cells strictly serially.
fn measure(
    problem: &Problem,
    variant: Variant,
    ts: usize,
    theta: &[f64],
    budget: Option<usize>,
    k: usize,
) -> (f64, Option<usize>, u64, u64, u64) {
    let mut ctx = ExecCtx::new(2, ts, Policy::Lws);
    ctx.tile_budget = budget;
    let (w0, r0, f0) = (tile_spill_writes(), tile_spill_reads(), tile_prefetches());
    let mut session = EvalSession::new(problem, variant, &ctx).unwrap();
    session.eval(theta).unwrap(); // warm the distance cache + workspace
    let warm = time_median(k, || {
        session.eval(theta).unwrap();
    });
    let peak = session.peak_resident_tile_bytes();
    (
        warm,
        peak,
        tile_spill_writes() - w0,
        tile_spill_reads() - r0,
        tile_prefetches() - f0,
    )
}

fn main() {
    let quick = quick();
    let full = std::env::var("BENCH_FULL").is_ok();

    // One streamed SST day, OLS-demeaned — the tutorial's fit input.
    let cfg = SstConfig {
        ny: 32,
        nx: 80,
        days: 1,
        ..SstConfig::default()
    };
    let gen_ctx = ExecCtx::new(2, 64, Policy::Lws);
    let day = stream_days(&cfg, &gen_ctx)
        .next()
        .expect("one day configured")
        .unwrap();
    let (locs, z) = day.valid_observations();
    let (_coef, resid) = ols_linear_mean(&locs, &z);
    let theta = day.theta_true;

    let sizes: Vec<usize> = if full {
        vec![480, 960, locs.len()]
    } else if quick {
        vec![240, 480]
    } else {
        vec![240, 480, 960]
    };
    let ts = 64;
    let k = if quick { 2 } else { 5 };

    println!(
        "SST scaling — warm exact eval, resident vs budget=dense/3 vs MP; grid {}x{} ({} valid), ts={ts}",
        cfg.ny,
        cfg.nx,
        locs.len()
    );
    header(&[
        "n", "variant", "mode", "warm s", "peak MiB", "budg MiB", "writes", "reads",
    ]);

    let mib = |b: Option<usize>| match b {
        Some(b) => format!("{:.2}", b as f64 / (1024.0 * 1024.0)),
        None => "-".into(),
    };
    let mut rows: Vec<BenchRow> = Vec::new();
    for &n_target in &sizes {
        let n = n_target.min(locs.len());
        let problem = Problem {
            kernel: kernel_by_name("ugsm-s").unwrap().into(),
            locs: Arc::new(locs[..n].to_vec()),
            z: Arc::new(resid[..n].to_vec()),
            metric: DistanceMetric::Euclidean,
        };
        let budget = (dense_lower_bytes(n, ts) / 3).max(1);
        let cells: [(Variant, &'static str, Option<usize>, String); 3] = [
            (Variant::Exact, "exact", None, "resident".into()),
            (Variant::Exact, "exact", Some(budget), "budget_dense/3".into()),
            (Variant::Mp { band: 1 }, "mp_band1", None, "resident".into()),
        ];
        for (variant, vname, b, mode) in cells {
            let (warm, peak, w, r, p) = measure(&problem, variant, ts, &theta, b, k);
            row(&[
                format!("{n}"),
                vname.into(),
                mode.clone(),
                s(warm),
                mib(peak),
                mib(b),
                format!("{w}"),
                format!("{r}"),
            ]);
            if let (Some(peak), Some(b)) = (peak, b) {
                assert!(
                    peak <= b.max(6 * ts * ts * 8),
                    "peak resident {peak} B exceeds clamped budget at n={n}"
                );
            }
            rows.push(BenchRow {
                n,
                variant: vname,
                mode,
                warm_s: warm,
                peak_bytes: peak,
                budget_bytes: b,
                spill_writes: w,
                spill_reads: r,
                prefetches: p,
            });
        }
    }

    // Gate metrics: the largest-n exact pair.
    let n_max = rows.iter().map(|r| r.n).max().unwrap();
    let pick = |mode_resident: bool| {
        rows.iter()
            .find(|r| {
                r.n == n_max && r.variant == "exact" && (r.budget_bytes.is_none()) == mode_resident
            })
            .expect("both exact cells measured")
    };
    let resident = pick(true);
    let budgeted = pick(false);
    println!(
        "\nn={n_max}: resident {:.4}s, budgeted {:.4}s ({:.2}x), peak {} within budget {}",
        resident.warm_s,
        budgeted.warm_s,
        budgeted.warm_s / resident.warm_s,
        mib(budgeted.peak_bytes),
        mib(budgeted.budget_bytes),
    );

    let jnum = |v: f64| -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "null".into()
        }
    };
    let jopt = |v: Option<usize>| -> String {
        match v {
            Some(v) => format!("{v}"),
            None => "null".into(),
        }
    };
    let rows_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"n\": {}, \"variant\": \"{}\", \"mode\": \"{}\", \
                 \"warm_eval_s\": {}, \"peak_resident_bytes\": {}, \
                 \"budget_bytes\": {}, \"spill_writes\": {}, \
                 \"spill_reads\": {}, \"prefetches\": {}}}",
                r.n,
                r.variant,
                r.mode,
                jnum(r.warm_s),
                jopt(r.peak_bytes),
                jopt(r.budget_bytes),
                r.spill_writes,
                r.spill_reads,
                r.prefetches
            )
        })
        .collect();
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"sst_scaling\",\n");
    json.push_str(&format!(
        "  \"grid\": {{\"ny\": {}, \"nx\": {}, \"valid\": {}}},\n  \"ts\": {ts},\n",
        cfg.ny,
        cfg.nx,
        locs.len()
    ));
    json.push_str(&format!("  \"rows\": [\n{}\n  ],\n", rows_json.join(",\n")));
    json.push_str(&format!(
        "  \"spill\": {{\n    \"n\": {n_max},\n    \"resident_warm_eval_s\": {},\n    \
         \"budget_warm_eval_s\": {},\n    \"budget_over_resident\": {}\n  }}\n}}\n",
        jnum(resident.warm_s),
        jnum(budgeted.warm_s),
        jnum(budgeted.warm_s / resident.warm_s)
    ));
    let out = bench_out_path("BENCH_sst_scaling.json");
    std::fs::write(&out, &json)
        .unwrap_or_else(|e| eprintln!("cannot write {}: {e}", out.display()));
    println!("telemetry written to {}", out.display());
}
