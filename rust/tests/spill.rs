//! Out-of-core tile-store conformance: a memory budget must change
//! *memory behaviour*, never *results*.
//!
//! The contract under test (DESIGN.md §2h):
//! * exact and DST likelihoods under a tiny budget are **bit-identical**
//!   to the fully resident path (the spill sweep executes the same plan
//!   in serial plan order and spill round-trips bytes exactly);
//! * MP agrees to ~1e-13 relative (f32 off-band arithmetic, different
//!   but equally valid reduction grouping);
//! * a budgeted run's peak resident tile bytes never exceed the budget,
//!   even when the dense working set is several times larger;
//! * the spill/prefetch counters fire under a binding budget and stay
//!   flat on the resident fast path.
//!
//! Every test takes the file-global lock: the spill counters are
//! process-wide (the I/O lane is a separate thread), so counter-delta
//! assertions must not observe a concurrent budgeted run — and the
//! cheapest way to guarantee that inside one test binary is to
//! serialize all of them (same pattern as `rust/tests/pack_alloc.rs`).

use exageostat::api::{mle_with_session, MleOptions};
use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{self, EvalSession, ExecCtx, Problem, Variant};
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use exageostat::testkit::{forall, gen, tile_prefetches, tile_spill_reads, tile_spill_writes};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn problem(n: usize, seed: u64) -> Problem {
    let mut rng = Pcg64::seed_from_u64(seed);
    Problem {
        kernel: kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(gen::locations(&mut rng, n)),
        z: Arc::new(gen::normals(&mut rng, n)),
        metric: DistanceMetric::Euclidean,
    }
}

/// A context with an *explicit* budget (`None` = fully resident even if
/// `EXAGEOSTAT_TILE_BUDGET` is set — these tests control both sides).
fn ctx_with(ncores: usize, ts: usize, budget: Option<usize>) -> ExecCtx {
    let mut ctx = ExecCtx::new(ncores, ts, Policy::Lws);
    ctx.tile_budget = budget;
    ctx
}

/// Dense lower-triangle footprint of the all-f64 workspace, in bytes.
fn dense_lower_bytes(n: usize, ts: usize) -> usize {
    let nt = n.div_ceil(ts);
    let dim = |t: usize| if t + 1 == nt { n - t * ts } else { ts };
    let mut total = 0;
    for i in 0..nt {
        for j in 0..=i {
            total += dim(i) * dim(j) * 8;
        }
    }
    total
}

#[test]
fn spilled_exact_and_dst_bit_identical_to_resident() {
    let _g = lock();
    // Random non-dividing grids: n = k*ts + r with 0 < r < ts, so edge
    // tiles are genuinely smaller and the slot/offset bookkeeping is
    // exercised off the easy path.  Budget Some(1) clamps to the
    // store's minimum working set — maximal spill pressure.
    forall(
        0x5B1D,
        5,
        |rng| {
            let ts = 9 + rng.below(12); // 9..=20
            let k = 2 + rng.below(3); // 2..=4 full tiles per side
            let n = k * ts + 1 + rng.below(ts - 1);
            let band = rng.below(3); // DST band 0..=2
            (n, ts, band)
        },
        |&(n, ts, band)| {
            let p = problem(n, 77 + n as u64);
            let theta = [1.1, 0.12, 0.5];
            for variant in [Variant::Exact, Variant::Dst { band }] {
                let resident =
                    likelihood::loglik(&p, &theta, variant, &ctx_with(2, ts, None)).unwrap();
                let spilled =
                    likelihood::loglik(&p, &theta, variant, &ctx_with(2, ts, Some(1))).unwrap();
                assert_eq!(
                    resident.loglik.to_bits(),
                    spilled.loglik.to_bits(),
                    "{variant:?} loglik differs at n={n} ts={ts}"
                );
                assert_eq!(resident.logdet.to_bits(), spilled.logdet.to_bits());
                assert_eq!(resident.sse.to_bits(), spilled.sse.to_bits());
            }
        },
    );
}

#[test]
fn spilled_mp_and_tlr_match_resident_tightly() {
    let _g = lock();
    let (n, ts) = (70, 16);
    let p = problem(n, 3);
    let theta = [1.0, 0.15, 1.0];
    for variant in [
        Variant::Mp { band: 1 },
        // TLR workspaces are rank-adaptive heap storage, not TileMatrix
        // tiles — a budget must be silently inert there, not an error.
        Variant::Tlr {
            tol: 1e-9,
            max_rank: usize::MAX,
        },
    ] {
        let resident = likelihood::loglik(&p, &theta, variant, &ctx_with(2, ts, None)).unwrap();
        let spilled = likelihood::loglik(&p, &theta, variant, &ctx_with(2, ts, Some(1))).unwrap();
        let rel = (resident.loglik - spilled.loglik).abs() / resident.loglik.abs();
        assert!(
            rel <= 1e-13,
            "{variant:?}: resident {} vs spilled {} (rel {rel})",
            resident.loglik,
            spilled.loglik
        );
    }
}

#[test]
fn budgeted_mle_completes_with_peak_resident_within_budget() {
    let _g = lock();
    // n chosen so the dense working set is several times the clamped
    // budget: the fit cannot complete without spilling.
    let (n, ts) = (120, 16);
    let p = problem(n, 11);
    let ctx = ctx_with(2, ts, Some(1));
    let mut session = EvalSession::new(&p, Variant::Exact, &ctx).unwrap();
    let budget = session.tile_budget().expect("budgeted session has a store");
    assert!(
        dense_lower_bytes(n, ts) > 3 * budget,
        "test must exceed the budget to mean anything"
    );
    let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 8);
    let r = mle_with_session(&mut session, &opt).unwrap();
    assert!(r.loglik.is_finite());
    assert!(r.iters > 0);
    let peak = session
        .peak_resident_tile_bytes()
        .expect("budgeted session tracks peak");
    assert!(
        peak <= budget,
        "peak resident {peak} B exceeds budget {budget} B"
    );
    // Sanity: the sweep actually used most of its allowance at some
    // point (an absurdly low peak would mean the budget never bound).
    assert!(peak * 2 > budget, "peak {peak} B vs budget {budget} B");
}

#[test]
fn spill_counters_fire_under_budget_and_stay_flat_resident() {
    let _g = lock();
    let (n, ts) = (54, 16);
    let p = problem(n, 21);
    let theta = [0.9, 0.1, 0.5];

    // Resident fast path: zero spill traffic.
    let (w0, r0, f0) = (tile_spill_writes(), tile_spill_reads(), tile_prefetches());
    likelihood::loglik(&p, &theta, Variant::Exact, &ctx_with(2, ts, None)).unwrap();
    assert_eq!(tile_spill_writes(), w0, "resident eval wrote spill");
    assert_eq!(tile_spill_reads(), r0, "resident eval read spill");
    assert_eq!(tile_prefetches(), f0, "resident eval prefetched");

    // Binding budget: the sweep must both write out and read back.
    likelihood::loglik(&p, &theta, Variant::Exact, &ctx_with(2, ts, Some(1))).unwrap();
    assert!(tile_spill_writes() > w0, "budgeted eval never spilled");
    assert!(tile_spill_reads() > r0, "budgeted eval never read back");
}

#[test]
fn env_budget_reaches_sessions_end_to_end() {
    let _g = lock();
    // The CI low-memory job sets EXAGEOSTAT_TILE_BUDGET for the whole
    // suite; this test pins the plumbing the job relies on — a context
    // built the normal way picks the env budget up and the session
    // reports it.  (Env mutation is why this test, too, needs the
    // file lock.)
    std::env::set_var("EXAGEOSTAT_TILE_BUDGET", "16K");
    let ctx = ExecCtx::new(1, 16, Policy::Eager);
    std::env::remove_var("EXAGEOSTAT_TILE_BUDGET");
    let p = problem(40, 31);
    let session = EvalSession::new(&p, Variant::Exact, &ctx).unwrap();
    let budget = session.tile_budget().expect("env budget ignored");
    // 16K requested; ts=16 makes the minimum working set 6*16*16*8 =
    // 12288 B, below the request, so the budget passes through intact.
    assert_eq!(budget, 16 * 1024);
    drop(session);
    // "off" disables it again.
    std::env::set_var("EXAGEOSTAT_TILE_BUDGET", "off");
    let ctx2 = ExecCtx::new(1, 16, Policy::Eager);
    std::env::remove_var("EXAGEOSTAT_TILE_BUDGET");
    assert!(ctx2.tile_budget.is_none());
}
