//! `EvalSession` integration suite: warm (cached-distance,
//! reused-workspace) evaluations must match a cold fresh-`Problem`
//! evaluation to <= 1e-12 across kernels (univariate, nugget, bivariate),
//! both distance metrics and tile sizes that do not divide `n` — and warm
//! iterations must allocate zero new tile matrices (the workspace-reuse
//! invariant, guarded through the `testkit` allocation counter).

use exageostat::covariance::{kernel_by_name, DistanceMetric, Location};
use exageostat::likelihood::{self, EvalSession, ExecCtx, Problem, Variant};
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use exageostat::testkit::tile_matrix_allocs;
use std::sync::Arc;

/// Random problem for `kernel` under `metric`.  Euclidean locations live
/// in the unit square; great-circle locations are (lon, lat) degrees over
/// a ~400 km patch, with range parameters in km.
fn make_problem(kernel: &str, metric: DistanceMetric, n: usize, seed: u64) -> Problem {
    let mut rng = Pcg64::seed_from_u64(seed);
    let locs: Vec<Location> = (0..n)
        .map(|_| match metric {
            DistanceMetric::Euclidean => Location::new(rng.next_f64(), rng.next_f64()),
            DistanceMetric::GreatCircle => {
                Location::new(20.0 + 4.0 * rng.next_f64(), -40.0 + 4.0 * rng.next_f64())
            }
        })
        .collect();
    let k: Arc<dyn exageostat::covariance::CovKernel> = kernel_by_name(kernel).unwrap().into();
    let dim = k.nvariates() * n;
    let z: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
    Problem {
        kernel: k,
        locs: Arc::new(locs),
        z: Arc::new(z),
        metric,
    }
}

/// Warm evaluations (3 passes) must reproduce the cold path exactly; if
/// the cold path rejects the configuration (non-SPD), so must the warm
/// one — the session may never silently diverge from `loglik`.
fn assert_warm_matches_cold(p: &Problem, theta: &[f64], variant: Variant, ts: usize) {
    let ctx = ExecCtx::new(2, ts, Policy::Lws);
    let cold = likelihood::loglik(p, theta, variant, &ctx);
    let mut s = EvalSession::new(p, variant, &ctx).unwrap();
    for pass in 0..3 {
        match (&cold, s.eval(theta)) {
            (Ok(c), Ok(w)) => {
                assert!(
                    (w.loglik - c.loglik).abs() <= 1e-12,
                    "{} {:?} {variant:?} ts={ts} pass {pass}: warm {} vs cold {}",
                    p.kernel.name(),
                    p.metric,
                    w.loglik,
                    c.loglik
                );
                assert!((w.logdet - c.logdet).abs() <= 1e-12);
                assert!((w.sse - c.sse).abs() <= 1e-12);
            }
            (Err(_), Err(_)) => {}
            (c, w) => panic!(
                "{} {:?} {variant:?} ts={ts}: cold {c:?} but warm {w:?}",
                p.kernel.name(),
                p.metric
            ),
        }
    }
}

/// Variants applicable to a kernel (TLR is univariate-only; bivariate DST
/// keeps the full band, since an unreordered multivariate band-1 matrix
/// can lose positive definiteness — parity must compare *successful*
/// evaluations too, not only matching failures).
fn variants_for(p: &Problem, ts: usize) -> Vec<Variant> {
    let nt = p.dim().div_ceil(ts);
    let mut v = vec![Variant::Exact, Variant::Mp { band: 1 }];
    if p.kernel.nvariates() == 1 {
        v.push(Variant::Dst { band: 1 });
        v.push(Variant::Tlr {
            tol: 1e-7,
            max_rank: usize::MAX,
        });
    }
    // Full band always succeeds, so DST parity is exercised on a
    // successful evaluation for every kernel/metric combination.
    v.push(Variant::Dst { band: nt - 1 });
    v
}

#[test]
fn warm_matches_cold_euclidean() {
    let n = 45; // 45 % 16 = 13, 45 % 10 = 5: edge tiles everywhere
    for (kernel, theta) in [
        ("ugsm-s", vec![1.2, 0.15, 1.0]),
        ("ugsmn-s", vec![1.0, 0.15, 0.5, 0.3]),
        ("bgspm-s", vec![1.0, 1.4, 0.15, 0.6, 1.2, 0.3]),
    ] {
        let p = make_problem(kernel, DistanceMetric::Euclidean, n, 0xE0C1);
        for ts in [16usize, 10] {
            for variant in variants_for(&p, ts) {
                assert_warm_matches_cold(&p, &theta, variant, ts);
            }
        }
    }
}

#[test]
fn warm_matches_cold_great_circle() {
    let n = 45;
    for (kernel, theta) in [
        ("ugsm-s", vec![1.0, 60.0, 0.5]),
        ("ugsmn-s", vec![1.0, 60.0, 0.5, 0.2]),
        ("bgspm-s", vec![1.0, 1.4, 60.0, 0.6, 1.2, 0.3]),
    ] {
        let p = make_problem(kernel, DistanceMetric::GreatCircle, n, 0x6C71);
        for ts in [16usize, 10] {
            for variant in variants_for(&p, ts) {
                assert_warm_matches_cold(&p, &theta, variant, ts);
            }
        }
    }
}

#[test]
fn warm_iterations_allocate_zero_tile_matrices() {
    let p = make_problem("ugsm-s", DistanceMetric::Euclidean, 40, 0xA110);
    let ctx = ExecCtx::new(2, 16, Policy::Lws);
    let thetas = [[1.0, 0.08, 0.5], [1.5, 0.12, 1.0], [0.8, 0.1, 0.5]];
    for variant in [
        Variant::Exact,
        Variant::Dst { band: 1 },
        Variant::Mp { band: 1 },
        Variant::Tlr {
            tol: 1e-7,
            max_rank: usize::MAX,
        },
    ] {
        let mut s = EvalSession::new(&p, variant, &ctx).unwrap();
        s.eval(&thetas[0]).unwrap();
        let base = tile_matrix_allocs();
        // Iterations >= 2 must construct zero new tile matrices: the
        // session's workspace-reuse invariant, pinned against refactors.
        s.eval(&thetas[1]).unwrap();
        s.eval(&thetas[2]).unwrap();
        assert_eq!(
            tile_matrix_allocs(),
            base,
            "{variant:?}: warm iterations allocated tile matrices"
        );
        assert_eq!(s.evals(), 3);
    }
    // Control: the counter is live — every cold evaluation allocates.
    let before = tile_matrix_allocs();
    likelihood::loglik(&p, &thetas[0], Variant::Exact, &ctx).unwrap();
    assert!(tile_matrix_allocs() > before, "cold path must allocate");
}
