//! Sharded-vs-single-runtime conformance: partitioning a tiled pipeline
//! 2-D block-cyclic across N runtimes (`pipeline::shard`) is a pure
//! scheduling transform — every plan edge is preserved (same-stage edges
//! stay graph edges, cross-shard edges become mailbox waits) and the
//! log-det reduction keeps its host-side order, so all-f64 variants
//! (Exact, DST) must reproduce the unsharded result **to the bit** at
//! every shard count.  MP runs the identical op stream through f32
//! kernels and TLR through ACA compression, so they assert through a
//! 1e-13 relative bound instead (same honesty hedge as the fusion
//! conformance suite).
//!
//! Problem sizes deliberately include tile sizes that do not divide `n`
//! and shard counts that do not divide the tile grid.

use exageostat::covariance::{DistanceMetric, Location};
use exageostat::likelihood::{self, EvalSession, ExecCtx, Problem, Variant};
use exageostat::pipeline::shard::ShardSet;
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use exageostat::testkit::{forall, gen};
use std::sync::Arc;

#[derive(Debug)]
struct Case {
    n: usize,
    ts: usize,
    locs: Vec<Location>,
    z: Vec<f64>,
    theta: [f64; 3],
}

fn gen_case(rng: &mut Pcg64) -> Case {
    // 40..=90 over small non-dividing tile sizes: 3..=13 tiles per side,
    // so 2 and 4 shards genuinely interleave (and never divide evenly).
    let n = 40 + rng.below(51);
    let ts = [7usize, 11, 16][rng.below(3)];
    Case {
        n,
        ts,
        locs: gen::locations(rng, n),
        z: gen::normals(rng, n),
        theta: gen::ugsm_theta(rng),
    }
}

fn problem(case: &Case) -> Problem {
    Problem {
        kernel: exageostat::covariance::kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(case.locs.clone()),
        z: Arc::new(case.z.clone()),
        metric: DistanceMetric::Euclidean,
    }
}

/// One full session evaluation under `nshards` (1 = plain single-runtime
/// execution, explicitly overriding any `EXAGEOSTAT_SHARDS` ambient set
/// so the baseline really is unsharded).
fn eval_with_shards(case: &Case, variant: Variant, nshards: usize) -> likelihood::LogLik {
    let p = problem(case);
    let mut ctx = ExecCtx::new(2, case.ts, Policy::Lws);
    let owned = if nshards > 1 {
        let set = Arc::new(ShardSet::new(nshards, 1, Policy::Lws));
        ctx.shards = Some(set.clone());
        Some(set)
    } else {
        ctx.shards = None;
        None
    };
    let mut session = EvalSession::new(&p, variant, &ctx).unwrap();
    let r = session.eval(&case.theta).unwrap();
    drop(session);
    if let Some(set) = owned {
        set.shutdown();
    }
    r
}

#[test]
fn exact_and_dst_are_bit_identical_across_shard_counts() {
    forall(0x5AAD_0001, 6, gen_case, |case| {
        let band = case.n.div_ceil(case.ts).saturating_sub(1).max(1);
        for variant in [Variant::Exact, Variant::Dst { band }] {
            let base = eval_with_shards(case, variant, 1);
            for nshards in [2usize, 4] {
                let got = eval_with_shards(case, variant, nshards);
                for (name, g, b) in [
                    ("logdet", got.logdet, base.logdet),
                    ("sse", got.sse, base.sse),
                    ("loglik", got.loglik, base.loglik),
                ] {
                    assert_eq!(
                        g.to_bits(),
                        b.to_bits(),
                        "{variant:?} n={} ts={} shards={nshards}: {name} {g} != unsharded {b}",
                        case.n,
                        case.ts
                    );
                }
            }
        }
    });
}

#[test]
fn mp_and_tlr_conform_across_shard_counts() {
    forall(0x5AAD_0002, 5, gen_case, |case| {
        let variants = [
            Variant::Mp { band: 1 },
            Variant::Tlr {
                tol: 1e-9,
                max_rank: usize::MAX,
            },
        ];
        for variant in variants {
            let base = eval_with_shards(case, variant, 1);
            for nshards in [2usize, 4] {
                let got = eval_with_shards(case, variant, nshards);
                for (name, g, b) in [
                    ("logdet", got.logdet, base.logdet),
                    ("sse", got.sse, base.sse),
                    ("loglik", got.loglik, base.loglik),
                ] {
                    let tol = 1e-13 * (1.0 + b.abs());
                    assert!(
                        (g - b).abs() <= tol,
                        "{variant:?} n={} ts={} shards={nshards}: {name} {g} vs {b}",
                        case.n,
                        case.ts
                    );
                }
            }
        }
    });
}

/// `EXAGEOSTAT_SHARDS` wiring: whatever the ambient environment says is
/// exactly what `ExecCtx::new` contexts carry (the CI build-test job
/// runs this suite once with `EXAGEOSTAT_SHARDS=2`).
#[test]
fn env_shard_set_matches_environment() {
    use exageostat::pipeline::shard::shard_set_from_env;
    let want = std::env::var("EXAGEOSTAT_SHARDS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 2);
    let got = shard_set_from_env();
    match (want, &got) {
        (Some(n), Some(set)) => assert_eq!(set.nshards(), n),
        (None, None) => {}
        (w, g) => panic!(
            "EXAGEOSTAT_SHARDS={w:?} but shard_set_from_env -> {:?}",
            g.as_ref().map(|s| s.nshards())
        ),
    }
    let ctx = ExecCtx::new(1, 64, Policy::Lws);
    assert_eq!(
        ctx.shards.as_ref().map(|s| s.nshards()),
        got.map(|s| s.nshards())
    );
}

/// End-to-end through the serving layer: a 2-member
/// [`ShardedCoordinator`] (each member on its own 1-worker runtime, big
/// pipelines sharded across both) reproduces a plain [`Coordinator`]'s
/// MLE bit-for-bit, and aggregates its members' stats.
#[test]
fn sharded_coordinator_mle_matches_single_coordinator() {
    use exageostat::api::{Hardware, MleOptions};
    use exageostat::coordinator::{
        Coordinator, DataSpec, Dispatch, Outcome, Request, RequestKind, ShardedCoordinator,
    };
    use exageostat::scheduler::runtime::CancelToken;

    // ts 8 over n=160 gives a 20-tile grid — past the coordinator's
    // shard threshold, so the MLE's pipelines really partition across
    // both member runtimes.
    let hw = Hardware {
        ncores: 2,
        ts: 8,
        policy: Policy::Lws,
        ..Hardware::default()
    };
    let req = Request {
        data: DataSpec {
            n: 160,
            seed: 5,
            ..DataSpec::default()
        }
        .into(),
        kind: RequestKind::Mle {
            variant: Variant::Exact,
            opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 3),
        },
        priority: 0,
        deadline_ms: None,
    };

    let single = Coordinator::new(hw.clone());
    let r1 = single.run(req.clone()).unwrap();
    single.shutdown();

    let sc = ShardedCoordinator::new(hw, 2);
    assert_eq!(sc.nshards(), 2);
    let r2 = sc.run_with_cancel(req, &CancelToken::new()).unwrap();
    let st = sc.stats();
    assert_eq!(st.requests, 1);
    assert_eq!(st.worker_threads, 2);
    sc.shutdown_dispatch();

    match (r1.outcome, r2.outcome) {
        (Outcome::Mle(a), Outcome::Mle(b)) => {
            assert_eq!(
                a.loglik.to_bits(),
                b.loglik.to_bits(),
                "loglik {} vs {}",
                a.loglik,
                b.loglik
            );
            assert_eq!(a.iters, b.iters);
            for (x, y) in a.theta.iter().zip(&b.theta) {
                assert_eq!(x.to_bits(), y.to_bits(), "theta {x} vs {y}");
            }
        }
        other => panic!("unexpected outcomes: {other:?}"),
    }
}
