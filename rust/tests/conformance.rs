//! Cross-variant conformance property suite (`testkit::forall`): each
//! approximate likelihood variant must coincide with the Exact engine in
//! its exact limit —
//!
//! * DST with `band >= nt - 1` (no tile annihilated),
//! * TLR with `tol -> 0`, unbounded rank (no compression error),
//! * MP with `band >= nt` (no tile demoted to f32),
//!
//! across randomly drawn problem sizes, tile sizes (including ones that
//! do not divide `n`), parameter vectors and data.  The variant under
//! test evaluates through an [`EvalSession`] (the route `api::mle` uses);
//! the Exact reference evaluates through the cold `likelihood::loglik`
//! path, so every case also re-certifies warm-vs-cold agreement.

use exageostat::covariance::{DistanceMetric, Location};
use exageostat::likelihood::{self, EvalSession, ExecCtx, Problem, Variant};
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use exageostat::testkit::{forall, gen};
use std::sync::Arc;

#[derive(Debug)]
struct Case {
    n: usize,
    ts: usize,
    locs: Vec<Location>,
    z: Vec<f64>,
    theta: [f64; 3],
}

fn gen_case(rng: &mut Pcg64) -> Case {
    let n = 24 + rng.below(49); // 24..=72
    let ts = [7usize, 11, 16, 24][rng.below(4)];
    Case {
        n,
        ts,
        locs: gen::locations(rng, n),
        z: gen::normals(rng, n),
        theta: gen::ugsm_theta(rng),
    }
}

fn problem(case: &Case) -> Problem {
    Problem {
        kernel: exageostat::covariance::kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(case.locs.clone()),
        z: Arc::new(case.z.clone()),
        metric: DistanceMetric::Euclidean,
    }
}

/// Exact reference (cold path) vs. the variant under test (session path).
fn assert_conformance(case: &Case, variant: Variant, tol_scale: f64) {
    let p = problem(case);
    let ctx = ExecCtx::new(2, case.ts, Policy::Lws);
    let exact = likelihood::loglik(&p, &case.theta, Variant::Exact, &ctx).unwrap();
    let mut session = EvalSession::new(&p, variant, &ctx).unwrap();
    let got = session.eval(&case.theta).unwrap();
    let tol = tol_scale * (1.0 + exact.loglik.abs());
    assert!(
        (got.loglik - exact.loglik).abs() <= tol,
        "{variant:?} vs exact at n={} ts={} theta={:?}: {} vs {} (tol {tol:e})",
        case.n,
        case.ts,
        case.theta,
        got.loglik,
        exact.loglik
    );
    assert!((got.logdet - exact.logdet).abs() <= tol, "logdet mismatch");
    assert!((got.sse - exact.sse).abs() <= tol, "sse mismatch");
}

#[test]
fn dst_full_band_conforms_to_exact() {
    forall(0xD57_0001, 10, gen_case, |case| {
        let nt = case.n.div_ceil(case.ts);
        // band >= nt - 1 retains every lower tile; only the Morton
        // reordering (likelihood-invariant) separates it from Exact.
        assert_conformance(case, Variant::Dst { band: nt - 1 }, 1e-8);
    });
}

#[test]
fn tlr_tight_tolerance_conforms_to_exact() {
    // TLR compresses to a *relative* tile tolerance, so the exact-limit
    // error is the ACA threshold amplified by the conditioning of Sigma;
    // the generator keeps smoothness/range in the well-conditioned regime
    // (the regime TLR targets) while still randomizing every dimension.
    let gen_tlr = |rng: &mut Pcg64| {
        let n = 24 + rng.below(25); // 24..=48
        let ts = [7usize, 11, 16][rng.below(3)];
        let theta = [
            rng.uniform(0.5, 2.0),
            rng.uniform(0.03, 0.15),
            [0.5, 1.0][rng.below(2)],
        ];
        Case {
            n,
            ts,
            locs: gen::locations(rng, n),
            z: gen::normals(rng, n),
            theta,
        }
    };
    forall(0x71_0002, 8, gen_tlr, |case| {
        assert_conformance(
            case,
            Variant::Tlr {
                tol: 1e-15,
                max_rank: usize::MAX,
            },
            1e-8,
        );
    });
}

#[test]
fn mp_full_band_conforms_to_exact() {
    forall(0x3F_0003, 10, gen_case, |case| {
        let nt = case.n.div_ceil(case.ts);
        // band >= nt keeps every tile in f64: bit-identical to Exact.
        assert_conformance(case, Variant::Mp { band: nt }, 1e-8);
    });
}

/// The historical MP semantics ("demote-then-f64"): every tile generated
/// in f64, off-band tiles rounded through f32, then a fully-f64 tiled
/// factorization + forward solve.  The current MP path stores off-band
/// tiles as real f32 and computes their updates through the f32
/// micro-kernels, so it must track this oracle to f32-scale accuracy —
/// same rounded matrix, half-width arithmetic.
fn mp_demote_then_f64_oracle(
    p: &Problem,
    theta: &[f64],
    band: usize,
    ts: usize,
) -> exageostat::likelihood::LogLik {
    use exageostat::linalg::cholesky::{
        check_fail, new_fail_flag, submit_tiled_forward_solve_banded, submit_tiled_potrf,
        TileHandles,
    };
    use exageostat::linalg::tile::{TileMatrix, TileVector};
    use exageostat::scheduler::pool;
    use exageostat::scheduler::TaskGraph;

    let n = p.dim();
    let mut a = TileMatrix::zeros(n, ts);
    for i in 0..a.nt() {
        for j in 0..=i {
            let h = a.tile_rows(i);
            let w = a.tile_cols(j);
            let mut buf = vec![0.0f64; h * w];
            exageostat::covariance::fill_cov_tile(
                p.kernel.as_ref(),
                theta,
                &p.locs,
                p.metric,
                i * ts,
                j * ts,
                h,
                w,
                &mut buf,
            );
            if i - j > band {
                exageostat::likelihood::mp::demote_f32(&mut buf);
            }
            a.tile_mut(i, j).copy_from_slice(&buf);
        }
    }
    let mut g = TaskGraph::new();
    let hs = TileHandles::register(&mut g, a.nt());
    let fail = new_fail_flag();
    submit_tiled_potrf(&mut g, &a, &hs, None, &fail);
    let y = TileVector::from_slice(&p.z, ts);
    let yh = g.register_many(y.nt());
    submit_tiled_forward_solve_banded(&mut g, &a, &hs, &y, &yh, None);
    pool::run(&mut g, 2, exageostat::scheduler::pool::Policy::Lws);
    check_fail(&fail).expect("oracle factorization SPD");
    exageostat::likelihood::LogLik::assemble(2.0 * a.diag_sum(f64::ln), y.dot_self(), n)
}

#[test]
fn mp_f32_compute_tracks_demote_then_f64_oracle() {
    // Keep smoothness/range in the well-conditioned regime (as the TLR
    // exact-limit test does): f32 rounding of off-band tiles perturbs
    // eigenvalues by ~1e-7·σ², so a near-singular draw could lose
    // positive definiteness in *both* paths and test nothing.
    let gen_mp = |rng: &mut Pcg64| {
        let n = 24 + rng.below(49); // 24..=72
        let ts = [7usize, 11, 16, 24][rng.below(4)];
        let theta = [
            rng.uniform(0.5, 2.0),
            rng.uniform(0.03, 0.15),
            [0.5, 1.0][rng.below(2)],
        ];
        Case {
            n,
            ts,
            locs: gen::locations(rng, n),
            z: gen::normals(rng, n),
            theta,
        }
    };
    forall(0x3F_0004, 8, gen_mp, |case| {
        let p = problem(case);
        let nt = case.n.div_ceil(case.ts);
        let band = if nt > 1 { (nt - 1).min(1) } else { 0 };
        let oracle = mp_demote_then_f64_oracle(&p, &case.theta, band, case.ts);
        let ctx = ExecCtx::new(2, case.ts, Policy::Lws);
        let mut session = EvalSession::new(&p, Variant::Mp { band }, &ctx).unwrap();
        let got = session.eval(&case.theta).unwrap();
        // f32-scale agreement: identical rounded matrix, f32 vs f64
        // factorization arithmetic on the off-band tiles.
        let tol = 1e-3 * (1.0 + oracle.loglik.abs());
        assert!(
            (got.loglik - oracle.loglik).abs() <= tol,
            "n={} ts={} band={band} theta={:?}: f32-path {} vs demote-then-f64 {}",
            case.n,
            case.ts,
            case.theta,
            got.loglik,
            oracle.loglik
        );
        assert!((got.logdet - oracle.logdet).abs() <= tol, "logdet drift");
        assert!((got.sse - oracle.sse).abs() <= tol, "sse drift");
    });
}

/// The fusion planner is a pure re-grouping of the task-graph IR: for
/// every variant, a fused plan must reproduce the unfused plan's log-det
/// and SSE — bit-identically where the arithmetic is all-f64 (exact,
/// DST), and to 1e-13 relative otherwise (MP's f32 tiles, TLR's ACA
/// compression — both still run the identical op stream, but asserting
/// through the looser bound keeps the property honest if their kernels
/// ever gain reduction-order freedom).
#[test]
fn fused_plans_reproduce_unfused_results() {
    use exageostat::pipeline::set_fuse_override;
    forall(0xF05E_0005, 6, gen_case, |case| {
        let p = problem(case);
        let ctx = ExecCtx::new(2, case.ts, Policy::Lws);
        let nt = case.n.div_ceil(case.ts);
        let variants = [
            Variant::Exact,
            Variant::Dst { band: nt - 1 },
            Variant::Mp { band: 1 },
            Variant::Tlr {
                tol: 1e-9,
                max_rank: usize::MAX,
            },
        ];
        for variant in variants {
            let mut session = EvalSession::new(&p, variant, &ctx).unwrap();
            set_fuse_override(Some(false));
            let unfused = session.eval(&case.theta).unwrap();
            set_fuse_override(Some(true));
            let fused = session.eval(&case.theta).unwrap();
            set_fuse_override(None);
            let all_f64 = matches!(variant, Variant::Exact | Variant::Dst { .. });
            for (name, f, u) in [
                ("logdet", fused.logdet, unfused.logdet),
                ("sse", fused.sse, unfused.sse),
                ("loglik", fused.loglik, unfused.loglik),
            ] {
                if all_f64 {
                    assert_eq!(
                        f.to_bits(),
                        u.to_bits(),
                        "{variant:?} n={} ts={}: fused {name} {f} != unfused {u}",
                        case.n,
                        case.ts
                    );
                } else {
                    let tol = 1e-13 * (1.0 + u.abs());
                    assert!(
                        (f - u).abs() <= tol,
                        "{variant:?} n={} ts={}: fused {name} {f} vs unfused {u}",
                        case.n,
                        case.ts
                    );
                }
            }
        }
    });
}
