//! Heterogeneous worker-class integration suite (DESIGN.md §2i).
//!
//! The placement contract under test: worker classes decide only *where*
//! a task runs, never *what* it computes — so any class layout must
//! reproduce the homogeneous pool's results bit-for-bit, across every
//! likelihood variant, while the placer keeps the critical-path
//! factorization kinds (POTRF, TRSM) off classes that cannot run them
//! competitively.
//!
//! Tests that flip the process-global class override serialize on
//! `placement::class_test_lock()` (same pattern as the planner's fuse
//! override lock).

use exageostat::covariance::DistanceMetric;
use exageostat::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use exageostat::pipeline::{lower_tiled, plan, Op, PlanKnobs, TiledSpec};
use exageostat::rng::Pcg64;
use exageostat::scheduler::placement::{
    class_test_lock, set_class_override, ClassSpec, Placer, WorkerClass,
};
use exageostat::scheduler::pool::Policy;
use exageostat::testkit::gen;
use std::sync::Arc;

fn problem(n: usize, seed: u64) -> (Problem, [f64; 3]) {
    let mut rng = Pcg64::seed_from_u64(seed);
    let p = Problem {
        kernel: exageostat::covariance::kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(gen::locations(&mut rng, n)),
        z: Arc::new(gen::normals(&mut rng, n)),
        metric: DistanceMetric::Euclidean,
    };
    (p, gen::ugsm_theta(&mut rng))
}

/// Evaluate all four variants twice (cold + warm — the warm pass runs
/// with a populated per-class cost model, exercising the measured HEFT
/// path) and return the warm `(loglik, logdet, sse)` bit patterns.
fn eval_all(p: &Problem, theta: &[f64; 3], n: usize, ts: usize) -> Vec<(u64, u64, u64)> {
    let ctx = ExecCtx::new(3, ts, Policy::Lws);
    let nt = n.div_ceil(ts);
    let variants = [
        Variant::Exact,
        Variant::Dst { band: nt - 1 },
        Variant::Mp { band: 1 },
        Variant::Tlr {
            tol: 1e-9,
            max_rank: usize::MAX,
        },
    ];
    variants
        .iter()
        .map(|v| {
            let mut s = EvalSession::new(p, *v, &ctx).unwrap();
            s.eval(theta).unwrap();
            let r = s.eval(theta).unwrap();
            (r.loglik.to_bits(), r.logdet.to_bits(), r.sse.to_bits())
        })
        .collect()
}

/// Any class layout — default, forced single-class, or CPU + throttled
/// slow — must reproduce identical bits for every variant: placement
/// moves tasks between workers, and the dependency edges plus the
/// host-side reductions already fix the floating-point summation order.
#[test]
fn class_layouts_are_bit_identical_across_variants() {
    let _g = class_test_lock();
    let (p, theta) = problem(60, 0x9_1001);
    let (n, ts) = (60, 16);

    set_class_override(None);
    let baseline = eval_all(&p, &theta, n, ts);

    set_class_override(ClassSpec::parse("cpu:1"));
    let single = eval_all(&p, &theta, n, ts);

    set_class_override(ClassSpec::parse("cpu:2,slow:1"));
    let classed = eval_all(&p, &theta, n, ts);

    set_class_override(None);
    assert_eq!(baseline, single, "forced single-class drifted from default");
    assert_eq!(baseline, classed, "cpu+slow layout drifted from default");
}

/// The override visibly reaches the runtime `ExecCtx::new` spawns: a
/// `cpu:2,slow:1` spec fitted to 3 cores yields exactly those classes.
#[test]
fn class_override_reaches_exec_ctx_runtime() {
    let _g = class_test_lock();
    set_class_override(ClassSpec::parse("cpu:2,slow:1"));
    let ctx = ExecCtx::new(3, 16, Policy::Lws);
    let classes = ctx.runtime.classes();
    set_class_override(None);
    assert_eq!(
        classes,
        vec![(WorkerClass::Cpu, 2), (WorkerClass::Slow, 1)],
        "override did not reach the spawned runtime"
    );
    assert_eq!(ctx.runtime.nworkers(), 3);
}

/// Eligibility pins the factorization critical path: with a slow class
/// present, the placer routes some off-critical work (generation, GEMM
/// updates) to it but never a POTRF or TRSM — those kinds are declared
/// CPU-only, so no cost estimate can move them.
#[test]
fn placer_keeps_potrf_and_trsm_off_slow_class() {
    // Dense 5x5-tile Cholesky, unfused so every plan task is one IR op
    // and the op<->class mapping is directly inspectable.
    let spec = TiledSpec {
        n: 240,
        ts: 48,
        band: None,
        mp_band: None,
        tlr: false,
        with_solve: true,
        with_logdet: true,
        owners: 1,
    };
    let ir = lower_tiled(&spec);
    let mut pl = plan(&ir, &PlanKnobs { fuse: false });
    let placer = Placer::new(&[(WorkerClass::Cpu, 2), (WorkerClass::Slow, 1)]);
    let counts = placer.place(&mut pl);

    let placed: usize = counts.iter().map(|&(_, c)| c).sum();
    assert_eq!(placed, pl.tasks.len(), "placer must class every task");
    let slow_placed = counts
        .iter()
        .find(|(c, _)| *c == WorkerClass::Slow)
        .map_or(0, |&(_, c)| c);
    assert!(
        slow_placed > 0,
        "48x48 f64 tiles clear the small-tile gate, so HEFT should \
         offload some generation/update work to the slow class"
    );
    for t in &pl.tasks {
        if t.class != Some(WorkerClass::Slow) {
            continue;
        }
        for &o in &t.ops {
            assert!(
                !matches!(ir.nodes[o].op, Op::Potrf { .. } | Op::Trsm { .. }),
                "critical-path op {:?} placed on the slow class",
                ir.nodes[o].op
            );
        }
    }
}

/// Tiles below the small-tile threshold never leave the CPU class: the
/// transfer/latency overhead dominates, so the placer's eligibility gate
/// must keep them local regardless of load.
#[test]
fn small_tiles_stay_on_cpu() {
    let spec = TiledSpec {
        n: 64,
        ts: 8, // 8x8 f64 = 512 B, far below the 16 KiB gate
        band: None,
        mp_band: None,
        tlr: false,
        with_solve: false,
        with_logdet: false,
        owners: 1,
    };
    let ir = lower_tiled(&spec);
    let mut pl = plan(&ir, &PlanKnobs { fuse: false });
    Placer::new(&[(WorkerClass::Cpu, 1), (WorkerClass::Slow, 3)]).place(&mut pl);
    for t in &pl.tasks {
        assert_eq!(
            t.class,
            Some(WorkerClass::Cpu),
            "small-tile task {:?} escaped the CPU class",
            t.kind.name
        );
    }
}
