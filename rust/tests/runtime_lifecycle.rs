//! Runtime-lifecycle integration tests (ISSUE 3 acceptance):
//!
//! * a full `exact_mle` run spawns exactly `ncores` worker threads total
//!   (counter-verified), and warm MLE iterations spawn **zero** new OS
//!   threads;
//! * concurrent jobs on one `Runtime` reproduce their sequential
//!   log-likelihoods **bit-exactly** under all four scheduling policies;
//! * `finalize`/`shutdown` joins the workers, parked workers serve
//!   late-arriving jobs, and submission after shutdown panics;
//! * the coordinator serves concurrent client threads with dataset /
//!   session caching.
//!
//! The worker-spawn counter is process-global, so every test in this
//! file serializes on one lock — other test binaries run in separate
//! processes and cannot perturb it.

use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::coordinator::{Coordinator, DataSpec, Outcome, Request, RequestKind};
use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{self, EvalSession, ExecCtx, Problem, Variant};
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use exageostat::scheduler::runtime::Runtime;
use exageostat::scheduler::{Access, TaskGraph, TaskKind};
use exageostat::testkit;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

fn counter_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn hw(ncores: usize, ts: usize, policy: Policy) -> Hardware {
    Hardware {
        ncores,
        ts,
        policy,
        ..Hardware::default()
    }
}

fn mk_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Pcg64::seed_from_u64(seed);
    Problem {
        kernel: kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(testkit::gen::locations(&mut rng, n)),
        z: Arc::new(testkit::gen::normals(&mut rng, n)),
        metric: DistanceMetric::Euclidean,
    }
}

#[test]
fn full_exact_mle_spawns_exactly_ncores_threads() {
    let _g = counter_lock();
    let before = testkit::worker_threads_spawned();
    let exa = ExaGeoStat::init(hw(3, 32, Policy::Prio));
    let data = exa
        .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 120, 5)
        .unwrap();
    let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, 40);
    let r = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();
    assert!(r.iters > 5, "MLE actually iterated ({} iters)", r.iters);
    // The runtime's own ledger: its workers are the only threads it ever
    // spawned, and init + simulate + the full MLE reused them throughout.
    assert_eq!(exa.runtime().threads_spawned(), 3);
    assert_eq!(
        testkit::worker_threads_spawned() - before,
        3,
        "a full exact_mle run must spawn exactly ncores worker threads"
    );
    exa.finalize();
}

#[test]
fn warm_mle_iterations_spawn_zero_threads() {
    let _g = counter_lock();
    let ctx = ExecCtx::new(2, 16, Policy::Lws);
    let p = mk_problem(60, 9);
    let theta = [1.0, 0.1, 0.5];
    let mut s = EvalSession::new(&p, Variant::Exact, &ctx).unwrap();
    let first = s.eval(&theta).unwrap();
    let before = testkit::worker_threads_spawned();
    for _ in 0..10 {
        let warm = s.eval(&theta).unwrap();
        assert_eq!(warm.loglik.to_bits(), first.loglik.to_bits());
    }
    assert_eq!(
        testkit::worker_threads_spawned(),
        before,
        "warm MLE iterations must spawn zero new OS threads"
    );
}

#[test]
fn concurrent_jobs_match_sequential_exactly_under_every_policy() {
    let _g = counter_lock();
    let theta = [1.2, 0.12, 0.5];
    for policy in [Policy::Eager, Policy::Prio, Policy::Lws, Policy::Random] {
        let problems: Vec<Problem> = (0..4).map(|i| mk_problem(50 + 4 * i, 20 + i as u64)).collect();
        // Sequential reference: each job alone on a single-worker runtime,
        // through the same session-based evaluation path.
        let serial: Vec<f64> = problems
            .iter()
            .map(|p| {
                let ctx1 = ExecCtx::new(1, 16, policy);
                let mut sess = EvalSession::new(p, Variant::Exact, &ctx1).unwrap();
                let mut last = f64::NAN;
                for _ in 0..3 {
                    last = sess.eval(&theta).unwrap().loglik;
                }
                // The session path and the one-shot path agree to
                // rounding; the bit-exactness claim below is about
                // scheduling, verified against this same path.
                let cold = likelihood::loglik(p, &theta, Variant::Exact, &ctx1).unwrap();
                assert!((cold.loglik - last).abs() < 1e-12);
                last
            })
            .collect();
        // 4 client threads interleaving their jobs on one shared runtime.
        let shared = ExecCtx::new(3, 16, policy);
        let results = Mutex::new(vec![0.0f64; problems.len()]);
        std::thread::scope(|s| {
            for (i, p) in problems.iter().enumerate() {
                let ctx = shared.clone();
                let results = &results;
                s.spawn(move || {
                    let mut sess = EvalSession::new(p, Variant::Exact, &ctx).unwrap();
                    let mut last = f64::NAN;
                    for _ in 0..3 {
                        last = sess.eval(&theta).unwrap().loglik;
                    }
                    results.lock().unwrap()[i] = last;
                });
            }
        });
        let got = results.into_inner().unwrap();
        for (i, (g, s)) in got.iter().zip(&serial).enumerate() {
            assert_eq!(
                g.to_bits(),
                s.to_bits(),
                "{policy:?} job {i}: concurrent {g} vs sequential {s}"
            );
        }
    }
}

#[test]
fn parked_workers_serve_late_jobs_and_shutdown_joins() {
    let _g = counter_lock();
    let rt = Runtime::new(2, Policy::Eager);
    let run_job = |rt: &Runtime| {
        let mut g = TaskGraph::new();
        let h = g.register();
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..20 {
            let c = c.clone();
            g.submit(TaskKind::OTHER, &[(h, Access::RW)], 0, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let prof = rt.submit(g).wait();
        assert_eq!(prof.total_tasks(), 20);
        assert_eq!(c.load(Ordering::SeqCst), 20);
    };
    run_job(&rt);
    // Let the workers park, then hand them another job.
    std::thread::sleep(Duration::from_millis(50));
    run_job(&rt);
    assert_eq!(rt.threads_spawned(), 2, "idle parking must not respawn");
    rt.shutdown();
    assert!(rt.is_shut_down());
    // Submission after finalize is a caller bug and panics.
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut g = TaskGraph::new();
        let h = g.register();
        g.submit(TaskKind::OTHER, &[(h, Access::W)], 0, || {});
        let handle = rt.submit(g);
        std::mem::forget(handle); // unreachable; avoids a hanging Drop
    }));
    assert!(res.is_err(), "submit after shutdown must panic");
}

#[test]
fn coordinator_serves_concurrent_clients_with_caching() {
    let _g = counter_lock();
    let coord = Coordinator::new(hw(2, 32, Policy::Prio));
    let data = DataSpec {
        n: 90,
        seed: 3,
        ..DataSpec::default()
    };
    // Warm the dataset cache deterministically, then fan out.
    let sim = Request {
        data: data.clone().into(),
        kind: RequestKind::Simulate,
        priority: 0,
        deadline_ms: None,
    };
    let r0 = coord.run(sim).unwrap();
    assert!(matches!(r0.outcome, Outcome::Simulated { n: 90 }));

    let mle = |priority: u8| Request {
        data: data.clone().into(),
        kind: RequestKind::Mle {
            variant: Variant::Exact,
            opt: MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 12),
        },
        priority,
        deadline_ms: None,
    };
    let predict = Request {
        data: data.clone().into(),
        kind: RequestKind::Predict { grid: 5 },
        priority: 2,
        deadline_ms: None,
    };
    let reqs = vec![mle(0), mle(1), predict];
    let responses = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for r in &reqs {
            let coord = &coord;
            let responses = &responses;
            let r = r.clone();
            s.spawn(move || {
                responses.lock().unwrap().push(coord.run(r).unwrap());
            });
        }
    });
    let responses = responses.into_inner().unwrap();
    assert_eq!(responses.len(), 3);
    // All three rode the warmed dataset cache.
    assert!(responses.iter().all(|r| r.data_cache_hit));
    // The two identical MLEs share one session and agree bit-exactly.
    let logliks: Vec<f64> = responses
        .iter()
        .filter_map(|r| match &r.outcome {
            Outcome::Mle(m) => Some(m.loglik),
            _ => None,
        })
        .collect();
    assert_eq!(logliks.len(), 2);
    assert_eq!(logliks[0].to_bits(), logliks[1].to_bits());
    let st = coord.stats();
    assert_eq!(st.requests, 4);
    assert_eq!(st.errors, 0);
    assert_eq!(st.data_cache_hits, 3);
    // Concurrent identical MLEs may both miss the session cache before
    // either inserts (benign: first insert wins); at most one hit here.
    assert!(st.session_cache_hits <= 1);
    coord.shutdown();
}
