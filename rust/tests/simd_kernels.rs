//! SIMD-dispatch conformance suite (`testkit::forall`): the dispatched
//! micro-kernel paths must agree with the retained scalar oracle to
//! 1e-13 (f64 — the only permitted divergence is FMA vs separate
//! multiply/add rounding) across shapes that exercise every edge of the
//! packing layer: MR/NR edge strips, `k = 0`, alpha/beta special cases,
//! and leading dimensions that do not equal the row count.  The blocked
//! TRSM/SYRK rewrites are checked against their naive column-oriented
//! oracles, and the f32 (mixed-precision) path against the same scalar
//! reference at f32 scale.

use exageostat::linalg::blas::{
    detected_simd, dgemm_raw, dgemm_raw_at, dpotrf_raw, dpotrf_unblocked, dsyrk_ln_naive,
    dsyrk_ln_raw, dtrsm_llnn_naive, dtrsm_llnn_raw, dtrsm_lltn_naive, dtrsm_lltn_raw,
    dtrsm_rltn_naive, dtrsm_rltn_raw, gemm_mp_at, set_simd_override, simd_level, MatMut, MatRef,
    SimdLevel, Trans,
};
use exageostat::rng::Pcg64;
use exageostat::testkit::forall;

#[derive(Debug)]
struct GemmCase {
    m: usize,
    n: usize,
    k: usize,
    ta: Trans,
    tb: Trans,
    alpha: f64,
    beta: f64,
    /// Extra rows appended to every leading dimension (non-dividing lds).
    pad: usize,
}

fn gen_gemm(rng: &mut Pcg64) -> GemmCase {
    // Bias toward micro-tile edges: sizes straddling MR64=8 / NR64=6
    // multiples, plus degenerate k.
    let dims = [1usize, 5, 6, 7, 8, 9, 16, 17, 24, 48, 63, 64, 65, 96, 130];
    let m = dims[rng.below(dims.len())];
    let n = dims[rng.below(dims.len())];
    let k = if rng.below(12) == 0 {
        0
    } else {
        dims[rng.below(dims.len())]
    };
    let alphas = [1.0, -1.0, 0.0, 1.3];
    let betas = [1.0, 0.0, 0.7];
    GemmCase {
        m,
        n,
        k,
        ta: if rng.below(2) == 0 { Trans::N } else { Trans::T },
        tb: if rng.below(2) == 0 { Trans::N } else { Trans::T },
        alpha: alphas[rng.below(alphas.len())],
        beta: betas[rng.below(betas.len())],
        pad: rng.below(4),
    }
}

/// Uniform(-1, 1) entries, so the 1e-13 f64 tolerance is an honest bound
/// on FMA-vs-mul/add drift.
fn uniforms(rng: &mut Pcg64, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

fn uniforms32(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

/// Column-major operand with ld = rows + pad (non-dividing lds).
fn operand(rng: &mut Pcg64, rows: usize, cols: usize, pad: usize) -> (Vec<f64>, usize) {
    let ld = rows + pad;
    (uniforms(rng, ld * cols.max(1)), ld)
}

fn run_gemm_parity(case: &GemmCase, level: SimdLevel) {
    let seed = (case.m * 1_000_000 + case.n * 1_000 + case.k) as u64 ^ 0x5EED;
    let mut rng = Pcg64::seed_from_u64(seed);
    let (ar, ac) = match case.ta {
        Trans::N => (case.m, case.k),
        Trans::T => (case.k, case.m),
    };
    let (br, bc) = match case.tb {
        Trans::N => (case.k, case.n),
        Trans::T => (case.n, case.k),
    };
    let (a, lda) = operand(&mut rng, ar.max(1), ac, case.pad);
    let (b, ldb) = operand(&mut rng, br.max(1), bc, case.pad);
    let (c0, ldc) = operand(&mut rng, case.m, case.n, case.pad);

    let mut c_simd = c0.clone();
    dgemm_raw_at(
        level,
        case.ta,
        case.tb,
        case.m,
        case.n,
        case.k,
        case.alpha,
        &a,
        lda,
        &b,
        ldb,
        case.beta,
        &mut c_simd,
        ldc,
    );
    let mut c_scalar = c0.clone();
    dgemm_raw_at(
        SimdLevel::Scalar,
        case.ta,
        case.tb,
        case.m,
        case.n,
        case.k,
        case.alpha,
        &a,
        lda,
        &b,
        ldb,
        case.beta,
        &mut c_scalar,
        ldc,
    );
    let mut err = 0.0f64;
    let mut cmax = 0.0f64;
    for j in 0..case.n {
        for i in 0..case.m {
            let x = c_simd[i + j * ldc];
            let y = c_scalar[i + j * ldc];
            err = err.max((x - y).abs());
            cmax = cmax.max(y.abs());
        }
    }
    assert!(
        err <= 1e-13 * (1.0 + cmax),
        "{case:?} at {level:?}: err {err:e} (cmax {cmax:e})"
    );
    // Padding rows must never be touched.
    for j in 0..case.n {
        for i in case.m..ldc {
            assert_eq!(c_simd[i + j * ldc], c0[i + j * ldc], "padding clobbered");
        }
    }
}

#[test]
fn gemm_dispatch_matches_scalar_to_1e13() {
    let level = detected_simd();
    forall(0x51D_0001, 60, gen_gemm, |case| {
        run_gemm_parity(case, level);
    });
}

#[test]
fn gemm_f32_path_dispatch_matches_scalar() {
    let level = detected_simd();
    forall(0x51D_0002, 30, gen_gemm, |case| {
        let mut rng = Pcg64::seed_from_u64((case.m * 7919 + case.n * 131 + case.k) as u64);
        let (ar, ac) = match case.ta {
            Trans::N => (case.m, case.k),
            Trans::T => (case.k, case.m),
        };
        let (br, bc) = match case.tb {
            Trans::N => (case.k, case.n),
            Trans::T => (case.n, case.k),
        };
        let lda = ar.max(1) + case.pad;
        let ldb = br.max(1) + case.pad;
        let ldc = case.m + case.pad;
        let a = uniforms32(&mut rng, lda * ac.max(1));
        let b = uniforms32(&mut rng, ldb * bc.max(1));
        let c0 = uniforms(&mut rng, ldc * case.n.max(1));
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        gemm_mp_at(
            level,
            case.ta,
            case.tb,
            case.m,
            case.n,
            case.k,
            case.alpha,
            MatRef::F32(&a),
            lda,
            MatRef::F32(&b),
            ldb,
            case.beta,
            MatMut::F64(&mut c1),
            ldc,
        );
        gemm_mp_at(
            SimdLevel::Scalar,
            case.ta,
            case.tb,
            case.m,
            case.n,
            case.k,
            case.alpha,
            MatRef::F32(&a),
            lda,
            MatRef::F32(&b),
            ldb,
            case.beta,
            MatMut::F64(&mut c2),
            ldc,
        );
        let mut err = 0.0f64;
        let mut cmax = 0.0f64;
        for j in 0..case.n {
            for i in 0..case.m {
                err = err.max((c1[i + j * ldc] - c2[i + j * ldc]).abs());
                cmax = cmax.max(c2[i + j * ldc].abs());
            }
        }
        // f32-scale bound that grows with the accumulation magnitude
        // (|acc| reaches ~sqrt(k)·|ab| before the f64 merge).
        assert!(
            err <= 1e-4 * (1.0 + cmax),
            "{case:?}: f32-path divergence {err:e} (cmax {cmax:e})"
        );
    });
}

#[derive(Debug)]
struct TriCase {
    m: usize,
    n: usize,
    seed: u64,
}

fn gen_tri(rng: &mut Pcg64) -> TriCase {
    // Straddle the 64-wide trsm blocks and the 32-wide syrk blocks.
    let dims = [3usize, 17, 40, 64, 65, 100, 130];
    TriCase {
        m: dims[rng.below(dims.len())],
        n: dims[rng.below(dims.len())],
        seed: rng.next_u64(),
    }
}

fn spd_factor(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
    let mut a = vec![0.0; n * n];
    dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &b, n, &b, n, 0.0, &mut a, n);
    for i in 0..n {
        a[i + i * n] += n as f64;
    }
    dpotrf_raw(n, &mut a, n).unwrap();
    a
}

#[test]
fn blocked_trsm_family_matches_naive_oracles() {
    forall(0x51D_0003, 12, gen_tri, |case| {
        let mut rng = Pcg64::seed_from_u64(case.seed);
        let &TriCase { m, n, .. } = case;
        let l_n = spd_factor(&mut rng, n);
        let l_m = spd_factor(&mut rng, m);
        let b0: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();

        let mut b1 = b0.clone();
        let mut b2 = b0.clone();
        dtrsm_rltn_raw(m, n, &l_n, n, &mut b1, m);
        dtrsm_rltn_naive(m, n, &l_n, n, &mut b2, m);
        let err = b1.iter().zip(&b2).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10, "rltn {case:?}: {err:e}");

        let mut b1 = b0.clone();
        let mut b2 = b0.clone();
        dtrsm_llnn_raw(m, n, &l_m, m, &mut b1, m);
        dtrsm_llnn_naive(m, n, &l_m, m, &mut b2, m);
        let err = b1.iter().zip(&b2).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10, "llnn {case:?}: {err:e}");

        let mut b1 = b0.clone();
        let mut b2 = b0.clone();
        dtrsm_lltn_raw(m, n, &l_m, m, &mut b1, m);
        dtrsm_lltn_naive(m, n, &l_m, m, &mut b2, m);
        let err = b1.iter().zip(&b2).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10, "lltn {case:?}: {err:e}");
    });
}

#[test]
fn blocked_syrk_matches_naive_oracle() {
    forall(0x51D_0004, 12, gen_tri, |case| {
        let mut rng = Pcg64::seed_from_u64(case.seed ^ 0xABCD);
        let &TriCase { m: n, n: k, .. } = case;
        let a: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let c0: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        for beta in [0.0, 1.0, 0.7] {
            let mut c1 = c0.clone();
            let mut c2 = c0.clone();
            dsyrk_ln_raw(n, k, -1.0, &a, n, beta, &mut c1, n);
            dsyrk_ln_naive(n, k, -1.0, &a, n, beta, &mut c2, n);
            for j in 0..n {
                for i in j..n {
                    let d = (c1[i + j * n] - c2[i + j * n]).abs();
                    assert!(d < 1e-10, "syrk {case:?} beta={beta}: {d:e} at ({i},{j})");
                }
            }
        }
    });
}

#[test]
fn blocked_potrf_matches_unblocked() {
    // The blocked path (riding blocked TRSM/SYRK and therefore the
    // packed gemm) must agree with the unblocked reference.
    let mut rng = Pcg64::seed_from_u64(0x51D_0005);
    for n in [80usize, 130, 200] {
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &b, n, &b, n, 0.0, &mut a, n);
        for i in 0..n {
            a[i + i * n] += n as f64;
        }
        let mut blocked = a.clone();
        dpotrf_raw(n, &mut blocked, n).unwrap();
        let mut unblocked = a.clone();
        dpotrf_unblocked(n, &mut unblocked, n).unwrap();
        let mut err = 0.0f64;
        let mut scale = 1.0f64;
        for j in 0..n {
            for i in j..n {
                err = err.max((blocked[i + j * n] - unblocked[i + j * n]).abs());
                scale = scale.max(unblocked[i + j * n].abs());
            }
        }
        assert!(err / scale < 1e-10, "n={n}: rel err {:e}", err / scale);
    }
}

#[test]
fn process_override_forces_dispatch() {
    // The accept/reset side of `set_simd_override` lives here (not in
    // the lib unit tests) so its process-global mutation cannot race
    // other tests' implicit-dispatch kernel calls: every other test in
    // this binary pins its level through the `_at` entry points.
    let mut rng = Pcg64::seed_from_u64(0x51D_0006);
    let (m, n, k) = (33usize, 29usize, 40usize);
    let a = uniforms(&mut rng, m * k);
    let b = uniforms(&mut rng, k * n);
    let mut c_forced = vec![0.0f64; m * n];
    let mut c_explicit = vec![0.0f64; m * n];

    // Un-overridden dispatch honors EXAGEOSTAT_SIMD (the CI scalar job
    // runs with it set), so compare the reset against the pre-override
    // resolution rather than raw detection.
    let base = simd_level();
    assert!(set_simd_override(Some(SimdLevel::Scalar)));
    assert_eq!(simd_level(), SimdLevel::Scalar);
    // Implicit dispatch under the override == explicit scalar call.
    dgemm_raw(Trans::N, Trans::N, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c_forced, m);
    dgemm_raw_at(
        SimdLevel::Scalar,
        Trans::N,
        Trans::N,
        m,
        n,
        k,
        1.0,
        &a,
        m,
        &b,
        k,
        0.0,
        &mut c_explicit,
        m,
    );
    assert_eq!(c_forced, c_explicit, "override must force the scalar kernel");

    assert!(set_simd_override(None));
    assert_eq!(simd_level(), base);
}

#[test]
fn gemm_degenerate_dims_are_noops_or_scale_only() {
    // m == 0 / n == 0: untouched; k == 0 with beta: pure scale, at every
    // level.
    for level in [SimdLevel::Scalar, detected_simd()] {
        let a = vec![1.0f64; 4];
        let b = vec![1.0f64; 4];
        let mut c = vec![2.0f64; 4];
        dgemm_raw_at(level, Trans::N, Trans::N, 0, 2, 2, 1.0, &a, 1, &b, 2, 0.0, &mut c, 1);
        assert_eq!(c, vec![2.0; 4], "m=0 must not touch C");
        dgemm_raw_at(level, Trans::N, Trans::N, 2, 2, 0, 1.0, &a, 2, &b, 2, 0.5, &mut c, 2);
        assert_eq!(c, vec![1.0; 4], "k=0 is beta-scale only");
        let mut cn = vec![f64::NAN; 4];
        dgemm_raw_at(level, Trans::N, Trans::N, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut cn, 2);
        assert!(cn.iter().all(|v| v.is_finite()), "beta=0 overwrites NaN");
    }
}
