//! Cross-module integration tests: end-to-end pipelines over the public
//! API, plus property-based invariants via the `testkit` harness
//! (the proptest substitute — see DESIGN.md).

use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::covariance::{
    build_cov_dense, kernel_by_name, morton_perm, DistanceMetric, Location,
};
use exageostat::likelihood::{self, ExecCtx, Problem, Variant};
use exageostat::linalg::blas::dpotrf;
use exageostat::scheduler::pool::Policy;
use exageostat::simulation::GeoData;
use exageostat::testkit::{forall, gen};
use std::sync::Arc;

fn ctx(ts: usize) -> ExecCtx {
    ExecCtx::new(2, ts, Policy::Prio)
}

fn problem_from(locs: Vec<Location>, z: Vec<f64>) -> Problem {
    Problem {
        kernel: kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(locs),
        z: Arc::new(z),
        metric: DistanceMetric::Euclidean,
    }
}

// ---------------------------------------------------------------------------
// property: the tiled Cholesky factor reconstructs Sigma (L L^T = Sigma)
// ---------------------------------------------------------------------------
#[test]
fn prop_tiled_cholesky_reconstructs_covariance() {
    forall(
        0xC0FFEE,
        8,
        |rng| {
            let n = 16 + rng.below(48);
            let locs = gen::locations(rng, n);
            let theta = gen::ugsm_theta(rng);
            let ts = 8 + rng.below(24);
            (locs, theta, ts)
        },
        |(locs, theta, ts)| {
            let kernel = kernel_by_name("ugsm-s").unwrap();
            let sigma = build_cov_dense(kernel.as_ref(), theta, locs, DistanceMetric::Euclidean);
            let tm = exageostat::linalg::tile::TileMatrix::from_dense_lower(&sigma, *ts);
            let mut g = exageostat::scheduler::TaskGraph::new();
            let hs = exageostat::linalg::cholesky::TileHandles::register(&mut g, tm.nt());
            let fail = exageostat::linalg::cholesky::new_fail_flag();
            exageostat::linalg::cholesky::submit_tiled_potrf(&mut g, &tm, &hs, None, &fail);
            exageostat::scheduler::pool::run(&mut g, 3, Policy::Lws);
            exageostat::linalg::cholesky::check_fail(&fail).expect("SPD");
            let l = tm.to_dense_lower();
            let mut rec = exageostat::linalg::Matrix::zeros(sigma.rows(), sigma.cols());
            exageostat::linalg::blas::dgemm(false, true, 1.0, &l, &l, 0.0, &mut rec);
            let err = rec.max_abs_diff(&sigma);
            assert!(err < 1e-9, "reconstruction err {err}");
        },
    );
}

// ---------------------------------------------------------------------------
// property: likelihood is invariant under simultaneous permutation of
// (locations, observations) — the correctness basis of Morton reordering
// ---------------------------------------------------------------------------
#[test]
fn prop_loglik_permutation_invariant() {
    forall(
        0xBEEF01,
        6,
        |rng| {
            let n = 20 + rng.below(40);
            let locs = gen::locations(rng, n);
            let z = gen::normals(rng, n);
            let theta = gen::ugsm_theta(rng);
            (locs, z, theta)
        },
        |(locs, z, theta)| {
            let p1 = problem_from(locs.clone(), z.clone());
            let base = likelihood::loglik(&p1, theta, Variant::Exact, &ctx(16)).unwrap();
            let perm = morton_perm(locs);
            let locs2: Vec<_> = perm.iter().map(|&i| locs[i]).collect();
            let z2: Vec<_> = perm.iter().map(|&i| z[i]).collect();
            let p2 = problem_from(locs2, z2);
            let permuted = likelihood::loglik(&p2, theta, Variant::Exact, &ctx(16)).unwrap();
            assert!(
                (base.loglik - permuted.loglik).abs() < 1e-7,
                "{} vs {}",
                base.loglik,
                permuted.loglik
            );
        },
    );
}

// ---------------------------------------------------------------------------
// property: DST with full bandwidth == exact; TLR tol->0 == exact
// ---------------------------------------------------------------------------
#[test]
fn prop_approximations_have_exact_limits() {
    forall(
        0xBEEF02,
        5,
        |rng| {
            let n = 24 + rng.below(40);
            let locs = gen::locations(rng, n);
            let z = gen::normals(rng, n);
            let theta = gen::ugsm_theta(rng);
            (locs, z, theta)
        },
        |(locs, z, theta)| {
            let p = problem_from(locs.clone(), z.clone());
            let c = ctx(16);
            let nt = p.dim().div_ceil(16);
            let exact = likelihood::loglik(&p, theta, Variant::Exact, &c).unwrap();
            // DST internally Morton-reorders; full band is mathematically
            // exact but rounding differs slightly under permutation.
            let dst =
                likelihood::loglik(&p, theta, Variant::Dst { band: nt - 1 }, &c).unwrap();
            assert!((dst.loglik - exact.loglik).abs() < 1e-6);
            let tlr = likelihood::loglik(
                &p,
                theta,
                Variant::Tlr {
                    tol: 1e-14,
                    max_rank: usize::MAX,
                },
                &c,
            )
            .unwrap();
            assert!(
                (tlr.loglik - exact.loglik).abs() < 1e-5 * exact.loglik.abs(),
                "tlr {} exact {}",
                tlr.loglik,
                exact.loglik
            );
            let mp = likelihood::loglik(&p, theta, Variant::Mp { band: nt - 1 }, &c).unwrap();
            assert!((mp.loglik - exact.loglik).abs() < 1e-8);
        },
    );
}

// ---------------------------------------------------------------------------
// property: kriging reproduces observations with zero variance, and
// predictions fall inside the observed convex range for smooth fields
// ---------------------------------------------------------------------------
#[test]
fn prop_kriging_interpolates() {
    forall(
        0xBEEF03,
        6,
        |rng| {
            let n = 15 + rng.below(30);
            let locs = gen::locations(rng, n);
            let z = gen::normals(rng, n);
            let theta = gen::ugsm_theta(rng);
            (locs, z, theta)
        },
        |(locs, z, theta)| {
            let kernel = kernel_by_name("ugsm-s").unwrap();
            let pred = exageostat::prediction::exact_predict(
                kernel.as_ref(),
                theta,
                locs,
                z,
                &locs[..3],
                DistanceMetric::Euclidean,
                true,
            )
            .unwrap();
            for i in 0..3 {
                assert!((pred.mean[i] - z[i]).abs() < 1e-6, "interpolation");
                assert!(pred.variance.as_ref().unwrap()[i] < 1e-6, "zero variance");
            }
        },
    );
}

// ---------------------------------------------------------------------------
// property: every Table III kernel produces an SPD covariance over random
// configurations (validated parameters)
// ---------------------------------------------------------------------------
#[test]
fn prop_all_kernels_spd() {
    use exageostat::covariance::kernels::ALL_KERNELS;
    forall(
        0xBEEF04,
        6,
        |rng| {
            let n = 10 + rng.below(15);
            let locs: Vec<Location> = (0..n)
                .map(|i| {
                    Location::new_st(
                        rng.next_f64(),
                        rng.next_f64(),
                        (i % 4) as f64 * rng.uniform(0.1, 0.5),
                    )
                })
                .collect();
            (locs, rng.below(ALL_KERNELS.len()))
        },
        |(locs, kidx)| {
            let name = ALL_KERNELS[*kidx];
            let k = kernel_by_name(name).unwrap();
            let theta: Vec<f64> = match name {
                "ugsm-s" => vec![1.0, 0.1, 0.5],
                "ugsmn-s" => vec![1.0, 0.1, 0.5, 0.1],
                "bgspm-s" => vec![1.0, 1.5, 0.1, 0.5, 1.0, 0.3],
                "bgsfm-s" => vec![1.0, 1.2, 0.12, 0.1, 0.08, 0.5, 1.0, 0.9, 0.3],
                "tgspm-s" => vec![1.0, 1.2, 0.8, 0.1, 0.5, 1.0, 1.5, 0.3, 0.2, 0.25],
                "ugsm-st" => vec![1.0, 0.1, 1.0, 0.5, 0.8, 0.5],
                "bgsm-st" => vec![1.0, 1.3, 0.1, 1.0, 0.5, 1.0, 0.8, 0.5, 0.4],
                _ => unreachable!(),
            };
            k.validate(&theta).unwrap();
            let mut sigma = build_cov_dense(k.as_ref(), &theta, locs, DistanceMetric::Euclidean);
            for i in 0..sigma.rows() {
                sigma[(i, i)] += 1e-9;
            }
            dpotrf(&mut sigma).unwrap_or_else(|e| panic!("{name} not SPD: {e}"));
        },
    );
}

// ---------------------------------------------------------------------------
// end-to-end: full pipeline through the public API (Example 2 protocol)
// ---------------------------------------------------------------------------
#[test]
fn e2e_simulate_fit_predict_fisher() {
    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ts: 64,
        policy: Policy::Prio,
        ..Hardware::default()
    });
    let theta_true = [1.0, 0.1, 0.5];
    let data = exa
        .simulate_data_exact("ugsm-s", &theta_true, "euclidean", 300, 42)
        .unwrap();
    let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], 1e-4, 0);
    let fit = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();

    // MLE invariant
    let p = problem_from(data.locs.clone(), data.z.clone());
    let at_truth = likelihood::loglik(&p, &theta_true, Variant::Exact, &ctx(64)).unwrap();
    assert!(fit.loglik >= at_truth.loglik - 1e-2);

    // kriging beats the prior mean on held-out points
    let train = GeoData {
        locs: data.locs[..280].to_vec(),
        z: data.z[..280].to_vec(),
    };
    let pred = exa
        .exact_predict(&train, &data.locs[280..], "ugsm-s", "euclidean", &fit.theta, true)
        .unwrap();
    let mse: f64 = pred
        .mean
        .iter()
        .zip(&data.z[280..])
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / 20.0;
    let mse0: f64 = data.z[280..].iter().map(|v| v * v).sum::<f64>() / 20.0;
    assert!(mse < mse0);

    // Fisher std errs at the estimate are finite and positive
    let fr = exa
        .exact_fisher(&data.locs, "ugsm-s", "euclidean", &fit.theta)
        .unwrap();
    for e in &fr.std_errs {
        assert!(e.is_finite() && *e > 0.0);
    }

    // MLOE/MMOM of the fitted parameters vs truth is small
    let grid: Vec<Location> = (0..16)
        .map(|k| Location::new(0.1 + 0.05 * (k % 4) as f64, 0.1 + 0.05 * (k / 4) as f64))
        .collect();
    let mm = exa
        .exact_mloe_mmom(&data.locs, &grid, "ugsm-s", "euclidean", &theta_true, &fit.theta)
        .unwrap();
    assert!(mm.mloe >= -1e-9, "mloe {}", mm.mloe);
    assert!(mm.mloe < 0.5, "fitted parameters should be efficient: {}", mm.mloe);
    exa.finalize();
}

// ---------------------------------------------------------------------------
// end-to-end: all four MLE variants agree on an easy problem
// ---------------------------------------------------------------------------
#[test]
fn e2e_variant_mles_consistent() {
    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ts: 32,
        policy: Policy::Lws,
        ..Hardware::default()
    });
    let data = exa
        .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 160, 3)
        .unwrap();
    let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, 80);
    let exact = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt).unwrap();
    let tlr = exa
        .tlr_mle(&data, "ugsm-s", "euclidean", &opt, 1e-9, usize::MAX)
        .unwrap();
    let mp = exa.mp_mle(&data, "ugsm-s", "euclidean", &opt, 2).unwrap();
    for (name, r) in [("tlr", &tlr), ("mp", &mp)] {
        assert!(
            (r.loglik - exact.loglik).abs() < 0.05 * exact.loglik.abs(),
            "{name}: {} vs {}",
            r.loglik,
            exact.loglik
        );
    }
    exa.finalize();
}

// ---------------------------------------------------------------------------
// robustness (§III-D): near-duplicate locations — exact tolerates much
// smaller separations than the singularity threshold the R packages hit
// ---------------------------------------------------------------------------
#[test]
fn robustness_near_duplicate_locations() {
    let kernel = kernel_by_name("ugsm-s").unwrap();
    let theta = [1.0, 0.1, 0.5];
    let base: Vec<Location> = (0..30)
        .map(|i| Location::new((i % 6) as f64 * 0.2, (i / 6) as f64 * 0.2))
        .collect();
    // separation 1e-6: fine for our f64 Cholesky (paper: geoR/fields fail
    // near 1e-4, ExaGeoStat near 1e-8); an exact duplicate (sep = 0)
    // makes the covariance singular and must be reported cleanly.
    for (sep, expect_ok) in [(1e-6, true), (0.0, false)] {
        let mut locs = base.clone();
        locs.push(Location::new(base[0].x + sep, base[0].y));
        let z = vec![0.5; locs.len()];
        let p = problem_from(locs, z);
        let r = likelihood::loglik(&p, &theta, Variant::Exact, &ctx(8));
        assert_eq!(r.is_ok(), expect_ok, "sep {sep}: {r:?}");
        if !expect_ok {
            let msg = r.unwrap_err().to_string();
            assert!(msg.contains("not positive definite"), "{msg}");
        }
    }
    let _ = kernel;
}

// ---------------------------------------------------------------------------
// great-circle path end to end (the tutorial's dmetric option)
// ---------------------------------------------------------------------------
#[test]
fn e2e_great_circle_mle() {
    let exa = ExaGeoStat::init(Hardware {
        ncores: 1,
        ts: 64,
        ..Hardware::default()
    });
    // lon/lat degrees over a ~500 km patch; beta in km
    let mut rng = exageostat::rng::Pcg64::seed_from_u64(9);
    let x: Vec<f64> = (0..120).map(|_| 20.0 + 4.0 * rng.next_f64()).collect();
    let y: Vec<f64> = (0..120).map(|_| -40.0 + 4.0 * rng.next_f64()).collect();
    let data = exa
        .simulate_obs_exact(&x, &y, "ugsm-s", &[1.0, 80.0, 0.5], "great_circle", 5)
        .unwrap();
    let opt = MleOptions::new(vec![0.01, 1.0, 0.05], vec![10.0, 500.0, 3.0], 1e-4, 60);
    let r = exa.mle(&data, "ugsm-s", "great_circle", &opt, Variant::Exact).unwrap();
    assert!(r.theta[1] > 5.0 && r.theta[1] < 500.0, "beta(km) {}", r.theta[1]);
    exa.finalize();
}
