//! Pack-workspace allocation regression (dedicated binary).
//!
//! Warm `EvalSession` iterations must perform **zero pack-buffer
//! allocations on the runtime workers**: the GEMM/SYRK/TRSM tile tasks
//! pack into thread-local workspaces that persistent workers grow once
//! (pre-grown via `Runtime::prewarm_workers` at session build) and then
//! reuse for the rest of the process.
//!
//! This lives in its own integration-test binary on purpose: the
//! counter (`testkit::pack_buffer_allocs`) is process-global because
//! the allocations happen on worker threads while the assertion runs on
//! the submitting thread — any concurrently running test that executes
//! a kernel would perturb the count.  Cargo runs test binaries
//! sequentially, and this binary contains only serialized assertions.

use exageostat::covariance::{kernel_by_name, DistanceMetric, Location};
use exageostat::likelihood::{EvalSession, ExecCtx, Problem, Variant};
use exageostat::linalg::blas::{dgemm_raw, Trans};
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use exageostat::testkit::pack_buffer_allocs;
use std::sync::Arc;

fn make_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Pcg64::seed_from_u64(seed);
    let locs: Vec<Location> = (0..n)
        .map(|_| Location::new(rng.next_f64(), rng.next_f64()))
        .collect();
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    Problem {
        kernel: kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(locs),
        z: Arc::new(z),
        metric: DistanceMetric::Euclidean,
    }
}

#[test]
fn warm_iterations_allocate_zero_pack_buffers() {
    // ts large enough that tile GEMMs take the packed path (the naive
    // cutoff is m*n*k <= 4096), n spanning several tile rows so every
    // kernel kind (GEMM/SYRK/TRSM + MP's mixed forms) is exercised.
    let p = make_problem(120, 0x9ACC);
    let ctx = ExecCtx::new(2, 32, Policy::Lws);
    let thetas = [
        [1.0, 0.08, 0.5],
        [1.5, 0.12, 1.0],
        [0.8, 0.1, 0.5],
        [1.2, 0.09, 1.0],
    ];
    for variant in [Variant::Exact, Variant::Mp { band: 0 }] {
        let mut s = EvalSession::new(&p, variant, &ctx).unwrap();
        // Warm-up: lets every worker grow its workspace to the maximum
        // tile footprint (prewarm at session build already reserved it;
        // the extra evals make the invariant scheduling-independent).
        s.eval(&thetas[0]).unwrap();
        s.eval(&thetas[1]).unwrap();
        let base = pack_buffer_allocs();
        s.eval(&thetas[2]).unwrap();
        s.eval(&thetas[3]).unwrap();
        s.eval(&thetas[0]).unwrap();
        assert_eq!(
            pack_buffer_allocs(),
            base,
            "{variant:?}: warm iterations performed pack-buffer allocations"
        );
    }

    // Control: the counter is live — a packed gemm on a fresh thread
    // (whose thread-local workspace is cold) must allocate.
    let before = pack_buffer_allocs();
    std::thread::spawn(|| {
        let n = 64;
        let a = vec![1.0f64; n * n];
        let b = vec![0.5f64; n * n];
        let mut c = vec![0.0f64; n * n];
        dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n);
    })
    .join()
    .unwrap();
    assert!(pack_buffer_allocs() > before, "cold thread must allocate");
}
