//! Backend subsystem integration tests: engine selection, native-engine
//! parity with the dense likelihood oracle, and the guarantee that
//! artifact-free machines (no XLA, no `make artifacts`) never panic.

use exageostat::backend::{self, Backend, Engine as _};
use exageostat::covariance::{
    build_cov_dense, build_dist_block, fill_cov_tile, kernel_by_name, DistanceMetric, Location,
};
use exageostat::likelihood::{self, ExecCtx, Problem, Variant};
use exageostat::linalg::cholesky::dense_chol_solve;
use exageostat::rng::Pcg64;
use exageostat::runtime::artifacts_available;
use exageostat::scheduler::pool::Policy;
use std::sync::Arc;

/// Small synthetic grid with deterministic jitter (jitter keeps distances
/// generic; the grid keeps the problem well conditioned).
fn grid(side: usize, seed: u64) -> Vec<Location> {
    let mut rng = Pcg64::seed_from_u64(seed);
    (0..side * side)
        .map(|k| {
            let (i, j) = (k % side, k / side);
            Location::new(
                (i as f64 + 0.3 * rng.next_f64()) / side as f64,
                (j as f64 + 0.3 * rng.next_f64()) / side as f64,
            )
        })
        .collect()
}

#[test]
fn native_engine_matches_likelihood_oracle() {
    let engine = backend::create_engine(Backend::Native).unwrap();
    let kernel = kernel_by_name("ugsm-s").unwrap();
    let locs = grid(7, 11); // n = 49
    let mut rng = Pcg64::seed_from_u64(12);
    let z: Vec<f64> = (0..locs.len()).map(|_| rng.normal()).collect();
    for theta in [[1.0, 0.1, 0.5], [2.0, 0.2, 1.5], [0.7, 0.3, 1.0]] {
        let got = engine
            .loglik(kernel.as_ref(), &theta, &locs, &z, DistanceMetric::Euclidean)
            .unwrap();
        // Oracle: plain dense Cholesky log-likelihood.
        let mut sigma =
            build_cov_dense(kernel.as_ref(), &theta, &locs, DistanceMetric::Euclidean);
        let (logdet, y) = dense_chol_solve(&mut sigma, &z).expect("SPD");
        let sse: f64 = y.iter().map(|v| v * v).sum();
        let want = -0.5 * sse
            - 0.5 * logdet
            - 0.5 * locs.len() as f64 * (2.0 * std::f64::consts::PI).ln();
        assert!(
            (got.loglik - want).abs() < 1e-10,
            "theta={theta:?}: {} vs {want}",
            got.loglik
        );
        // And against the tiled likelihood engine (exact variant), which
        // routes tile generation through the same backend.
        let p = Problem {
            kernel: kernel_by_name("ugsm-s").unwrap().into(),
            locs: Arc::new(locs.clone()),
            z: Arc::new(z.clone()),
            metric: DistanceMetric::Euclidean,
        };
        let tiled =
            likelihood::loglik(&p, &theta, Variant::Exact, &ExecCtx::new(2, 16, Policy::Prio))
                .unwrap();
        assert!(
            (got.loglik - tiled.loglik).abs() < 1e-8,
            "theta={theta:?}: engine {} vs tiled {}",
            got.loglik,
            tiled.loglik
        );
    }
}

#[test]
fn engine_fill_tile_matches_covariance_kernels() {
    let engine = backend::default_engine();
    let kernel = kernel_by_name("ugsm-s").unwrap();
    let locs = grid(6, 21); // n = 36
    let theta = [1.4, 0.15, 0.5];
    for (row0, col0, h, w) in [(0usize, 0usize, 8usize, 8usize), (8, 0, 8, 8), (30, 12, 6, 9)] {
        let mut got = vec![0.0; h * w];
        engine.fill_tile(
            kernel.as_ref(),
            &theta,
            &locs,
            DistanceMetric::Euclidean,
            row0,
            col0,
            h,
            w,
            None,
            &mut got,
        );
        let mut want = vec![0.0; h * w];
        fill_cov_tile(
            kernel.as_ref(),
            &theta,
            &locs,
            DistanceMetric::Euclidean,
            row0,
            col0,
            h,
            w,
            &mut want,
        );
        assert_eq!(got, want, "tile ({row0},{col0},{h},{w})");
        // The precomputed-distance fast path of the new fill_tile
        // contract produces the identical tile.
        let block = build_dist_block(&locs, DistanceMetric::Euclidean, row0, col0, h, w);
        let mut cached = vec![0.0; h * w];
        engine.fill_tile(
            kernel.as_ref(),
            &theta,
            &locs,
            DistanceMetric::Euclidean,
            row0,
            col0,
            h,
            w,
            Some(&block),
            &mut cached,
        );
        assert_eq!(cached, want, "cached tile ({row0},{col0},{h},{w})");
    }
}

#[test]
fn backend_names_parse() {
    assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
    assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
    let err = Backend::parse("cuda").unwrap_err();
    assert!(err.to_string().contains("unknown backend"), "{err}");
}

#[test]
fn missing_artifacts_paths_never_panic() {
    if artifacts_available() {
        eprintln!("artifacts present — nothing to check for the artifact-free path");
        return;
    }
    // Requesting the PJRT backend on an artifact-free machine must fail
    // with a clean error (feature off: unavailable; feature on: missing
    // manifest / stub xla client) — never panic.
    let r = backend::create_engine(Backend::Pjrt);
    assert!(r.is_err(), "pjrt backend must not construct without artifacts");
    assert!(!format!("{:#}", r.unwrap_err()).is_empty());
    // The default engine must still be fully usable.
    let engine = backend::default_engine();
    if std::env::var("EXAGEOSTAT_BACKEND").is_err() {
        assert_eq!(engine.name(), "native");
    }
    let kernel = kernel_by_name("ugsm-s").unwrap();
    let locs = grid(4, 31);
    let mut out = vec![0.0; 16];
    engine.fill_tile(
        kernel.as_ref(),
        &[1.0, 0.1, 0.5],
        &locs,
        DistanceMetric::Euclidean,
        0,
        0,
        4,
        4,
        None,
        &mut out,
    );
    assert!(out.iter().all(|v| v.is_finite()));
    // ExecCtx::default() resolves an engine without panicking either.
    assert!(!ExecCtx::default().engine.name().is_empty());
}

/// `cargo test --features pjrt` (stub-backed in CI): the PJRT paths of
/// the fill_tile contract must degrade to native behaviour, not panic —
/// an unavailable XLA runtime means `create_engine(Pjrt)` fails cleanly,
/// and the degraded default engine still serves both the plain and the
/// precomputed-distance tile paths with native-identical results.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_feature_fallback_serves_fill_tile_contract() {
    if artifacts_available() && backend::create_engine(Backend::Pjrt).is_ok() {
        eprintln!("real PJRT runtime present — degradation path not exercised here");
        return;
    }
    let err = backend::create_engine(Backend::Pjrt).unwrap_err();
    assert!(!format!("{err:#}").is_empty());
    let engine = backend::default_engine();
    let kernel = kernel_by_name("ugsm-s").unwrap();
    let locs = grid(5, 41); // n = 25
    let theta = [1.0, 0.1, 0.5];
    let (row0, col0, h, w) = (8usize, 0usize, 8usize, 8usize);
    let mut want = vec![0.0; h * w];
    fill_cov_tile(
        kernel.as_ref(),
        &theta,
        &locs,
        DistanceMetric::Euclidean,
        row0,
        col0,
        h,
        w,
        &mut want,
    );
    for dist in [
        None,
        Some(build_dist_block(&locs, DistanceMetric::Euclidean, row0, col0, h, w)),
    ] {
        let mut got = vec![0.0; h * w];
        engine.fill_tile(
            kernel.as_ref(),
            &theta,
            &locs,
            DistanceMetric::Euclidean,
            row0,
            col0,
            h,
            w,
            dist.as_ref(),
            &mut got,
        );
        assert_eq!(got, want);
    }
}
