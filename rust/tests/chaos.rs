//! ISSUE 10 acceptance: chaos suite — the serving stack under seeded
//! fault injection (DESIGN.md §2j).
//!
//! The failure-model contract under test:
//!
//! * **recovery is invisible** — with a fault plan armed and retry
//!   budgets available, every request that completes is **bit-identical**
//!   to its fault-free run (injection fires at task entry, so a retried
//!   task re-executes from untouched inputs);
//! * **exhaustion is typed** — when budgets run out the caller gets a
//!   typed `TaskError` through the `anyhow` chain, never a hang or a
//!   poisoned runtime;
//! * **the serve loop survives** — injected faults fail individual
//!   requests at worst; the stream keeps admitting and every submitted
//!   request is accounted for exactly once;
//! * **deadlines fire as `TimedOut`** — a request past its `deadline_ms`
//!   reaps as `Completion::TimedOut`, distinct from user cancellation;
//! * **counters prove it happened** — the injection/retry counters are
//!   nonzero after an armed run (a chaos test that injected nothing
//!   tests nothing).
//!
//! Every test holds `fault_test_lock` across its armed window: the
//! plan, the retry overrides and the counters are process-global, so a
//! concurrent disarmed test must never observe someone else's faults.

use exageostat::api::Hardware;
use exageostat::coordinator::{
    parse_request, serve_stream, Client, Completion, Coordinator, Dispatch, Outcome, Request,
    ServeOptions, ShardedCoordinator,
};
use exageostat::scheduler::pool::Policy;
use exageostat::scheduler::runtime::{CancelToken, TaskError};
use exageostat::testkit::{
    fault_test_lock, faults_injected, set_fault_plan, set_job_retry_override,
    set_task_retry_override, tasks_retried, FaultPlan,
};
use std::sync::Arc;

fn hw(ncores: usize, ts: usize) -> Hardware {
    Hardware {
        ncores,
        ts,
        policy: Policy::Lws,
        ..Hardware::default()
    }
}

fn mle_req(variant: &str, n: usize, iters: usize) -> Request {
    let extra = match variant {
        "dst" | "mp" => ",\"band\":1".to_string(),
        "tlr" => ",\"tlr_tol\":1e-7".to_string(),
        _ => String::new(),
    };
    parse_request(&format!(
        "{{\"type\":\"mle\",\"variant\":\"{variant}\",\"n\":{n},\"seed\":11,\
         \"max_iters\":{iters},\"clb\":[0.01,0.01,0.01],\"tol\":1e-6{extra}}}"
    ))
    .unwrap()
}

fn mle_bits(resp: &Outcome) -> (Vec<u64>, u64) {
    match resp {
        Outcome::Mle(r) => (
            r.theta.iter().map(|t| t.to_bits()).collect(),
            r.loglik.to_bits(),
        ),
        other => panic!("expected an MLE outcome, got {other:?}"),
    }
}

/// Arm a moderately hostile plan with generous retry budgets: per-task
/// failure needs `panic_rate^(retries+1)` consecutive draws, so the
/// probability any job exhausts its budget is negligible while the
/// expected injection count over an MLE's hundreds of task draws is
/// large.
fn arm_recoverable(seed: u64) {
    set_task_retry_override(Some(4));
    set_job_retry_override(Some(2));
    set_fault_plan(Some(FaultPlan {
        panic_rate: 0.05,
        io_rate: 0.05,
        stall_rate: 0.01,
        stall_ms: 1,
        seed,
    }));
}

fn disarm() {
    set_fault_plan(None);
    set_task_retry_override(None);
    set_job_retry_override(None);
}

#[test]
fn recovered_requests_are_bit_identical_across_variants() {
    let _serial = fault_test_lock();
    disarm(); // baselines must be clean even if a prior armed test panicked

    // Fault-free baselines, one fresh coordinator per variant so cache
    // state cannot differ between the two runs.
    let variants = ["exact", "dst", "tlr", "mp"];
    let baseline: Vec<(Vec<u64>, u64)> = variants
        .iter()
        .map(|v| {
            let coord = Coordinator::new(hw(2, 32));
            let resp = coord.run(mle_req(v, 96, 6)).unwrap();
            coord.shutdown();
            mle_bits(&resp.outcome)
        })
        .collect();

    let f0 = faults_injected();
    arm_recoverable(42);
    for (v, base) in variants.iter().zip(&baseline) {
        let coord = Coordinator::new(hw(2, 32));
        let resp = coord
            .run(mle_req(v, 96, 6))
            .unwrap_or_else(|e| panic!("{v} under faults: {e:#}"));
        coord.shutdown();
        assert_eq!(
            &mle_bits(&resp.outcome),
            base,
            "{v}: recovered run differs from fault-free"
        );
    }
    // A tiny tile budget forces the spill executor + store I/O paths, so
    // the `io_rate` sites (spill read/write, prefetch) actually draw.
    {
        let coord = Coordinator::with_mem_budget(hw(2, 32), 64 * 1024);
        let resp = coord.run(mle_req("exact", 96, 6)).unwrap();
        coord.shutdown();
        assert_eq!(
            &mle_bits(&resp.outcome),
            &baseline[0],
            "spilled recovered run differs from fault-free"
        );
    }
    // Sharded route: the member coordinators share the process-global
    // injector; recovery must hold through the routing layer too.
    {
        let sc = ShardedCoordinator::new(hw(2, 32), 2);
        let resp = sc
            .run_with_cancel(mle_req("exact", 96, 6), &CancelToken::new())
            .unwrap();
        sc.shutdown_dispatch();
        assert_eq!(
            &mle_bits(&resp.outcome),
            &baseline[0],
            "sharded recovered run differs from fault-free"
        );
    }
    disarm();
    assert!(
        faults_injected() > f0,
        "armed chaos run injected no faults — the suite tested nothing"
    );
}

#[test]
fn exhausted_budgets_surface_typed_panic_not_hang() {
    let _serial = fault_test_lock();
    set_task_retry_override(Some(0));
    set_job_retry_override(Some(0));
    set_fault_plan(Some(FaultPlan {
        panic_rate: 1.0,
        ..FaultPlan::default()
    }));
    let coord = Coordinator::new(hw(1, 32));
    let err = coord.run(mle_req("exact", 64, 4)).unwrap_err();
    assert!(
        err.chain().any(|c| matches!(
            c.downcast_ref::<TaskError>(),
            Some(TaskError::Panic(m)) if m.contains("injected fault")
        )),
        "expected TaskError::Panic in the chain, got: {err:#}"
    );
    let st = coord.stats();
    assert_eq!(st.errors, 1, "{st:?}");
    assert_eq!(st.cancelled, 0, "panic miscounted as cancellation: {st:?}");
    assert!(st.faults_injected > 0, "{st:?}");
    coord.shutdown();
    disarm();
}

#[test]
fn whole_job_retry_recovers_after_task_budget_exhaustion() {
    let _serial = fault_test_lock();
    // No task-level retry at all: with a 15% panic rate a short simulate
    // job (a handful of task draws) fails often, so recovery can only
    // come from the coordinator's whole-job retry loop — fresh draws and
    // freshly evicted caches on every attempt.  Small jobs keep each
    // attempt cheap; 50 attempts make overall failure astronomically
    // unlikely while the first-attempt-always-clean case (which would
    // leave `job_retries` at zero) is vanishing across ten jobs.
    set_task_retry_override(Some(0));
    set_job_retry_override(Some(50));
    set_fault_plan(Some(FaultPlan {
        panic_rate: 0.15,
        ..FaultPlan::default()
    }));
    let r0 = tasks_retried();
    let coord = Coordinator::new(hw(1, 32));
    for seed in 0..10u64 {
        let req = parse_request(&format!(
            "{{\"type\":\"simulate\",\"n\":64,\"seed\":{seed}}}"
        ))
        .unwrap();
        let resp = coord.run(req).unwrap();
        assert!(matches!(resp.outcome, Outcome::Simulated { n: 64 }));
    }
    let st = coord.stats();
    coord.shutdown();
    disarm();
    assert_eq!(st.errors, 0, "all jobs must recover via job retry: {st:?}");
    assert_eq!(tasks_retried(), r0, "task retries were disabled");
    assert!(
        st.job_retries > 0,
        "ten faulted jobs with no task retries should have needed at \
         least one whole-job retry: {st:?}"
    );
}

#[test]
fn serve_stream_survives_chaos_and_accounts_every_request() {
    let _serial = fault_test_lock();
    arm_recoverable(7);
    let coord = Arc::new(Coordinator::new(hw(2, 32)));
    let client = Client::new(coord.clone(), 2);
    let mut lines = String::from("# chaos workload\n\n");
    for i in 0..8 {
        lines.push_str(&match i % 3 {
            0 => format!("{{\"type\":\"mle\",\"n\":80,\"seed\":{i},\"max_iters\":4}}\n"),
            1 => format!("{{\"type\":\"simulate\",\"n\":80,\"seed\":{i}}}\n"),
            _ => format!("{{\"type\":\"predict\",\"n\":80,\"seed\":{i},\"grid\":4}}\n"),
        });
    }
    lines.push_str("not json\n");
    let mut reader = std::io::BufReader::new(lines.as_bytes());
    let opts = ServeOptions {
        window: 2,
        depth_limit: None,
        deadline_ms: None,
    };
    let mut reaped = 0usize;
    let summary = serve_stream(&client, &mut reader, &opts, |_, _| reaped += 1)
        .expect("the serve loop itself must survive injected faults");
    disarm();
    assert_eq!(summary.submitted, 8, "{summary:?}");
    assert_eq!(summary.parse_errors, 1, "{summary:?}");
    assert_eq!(reaped, 8, "every admitted request reaps exactly once");
    assert_eq!(
        summary.ok + summary.failed + summary.cancelled + summary.timed_out,
        8,
        "unaccounted completions: {summary:?}"
    );
    assert!(
        summary.ok >= 1,
        "retry budgets should recover at least some requests: {summary:?}"
    );
    client.shutdown();
    coord.shutdown();
}

#[test]
fn expired_deadline_reaps_as_timed_out() {
    let _serial = fault_test_lock();
    disarm(); // a timeout test must not depend on injected stalls
    let coord = Arc::new(Coordinator::new(hw(1, 32)));
    let client = Client::new(coord.clone(), 1);
    let mut req = mle_req("exact", 300, 60);
    req.deadline_ms = Some(5);
    let done = client.submit(req).wait();
    assert!(
        matches!(done, Completion::TimedOut),
        "a 5 ms deadline on a multi-second MLE must reap TimedOut, got {done:?}"
    );
    // A deadline miss is a timeout, not a failure and not a cancel.
    let ok = client.submit(mle_req("exact", 64, 3)).wait();
    assert!(
        matches!(ok, Completion::Done(_)),
        "the runtime must stay serviceable after a timeout, got {ok:?}"
    );
    client.shutdown();
    coord.shutdown();
}
