//! ISSUE 4 acceptance: the unified async job API.
//!
//! * **Back-compat parity** — every legacy Table-II wrapper
//!   (`exact_mle`, `dst_mle`, `tlr_mle`, `mp_mle`, `exact_predict`) is
//!   bit-identical to the equivalent `ModelBuilder` + `Client::submit`
//!   route;
//! * **cancellation** — a cancelled job executes strictly fewer runtime
//!   tasks than a completed run of the same request, and
//!   `Ticket::wait` reports `Cancelled`;
//! * **typed errors** — misconfiguration surfaces as `ApiError`
//!   variants from both the builder and the legacy wrappers.

use exageostat::api::{ApiError, ExaGeoStat, GeoModel, Hardware, MleOptions};
use exageostat::coordinator::{Client, Completion, Coordinator, Outcome, Request};
use exageostat::likelihood::Variant;
use exageostat::scheduler::pool::Policy;
use exageostat::simulation::GeoData;
use std::sync::Arc;
use std::time::Duration;

fn hw(ncores: usize, ts: usize) -> Hardware {
    Hardware {
        ncores,
        ts,
        policy: Policy::Prio,
        ..Hardware::default()
    }
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}[{i}]: {x} vs {y} differ in bits"
        );
    }
}

#[test]
fn legacy_mle_wrappers_bit_match_builder_client_route() {
    let exa = ExaGeoStat::init(hw(2, 32));
    let data = exa
        .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 96, 5)
        .unwrap();
    let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 10);
    let coord = Arc::new(Coordinator::new(hw(2, 32)));
    let client = Client::new(coord.clone(), 2);

    let variants: [(&str, Variant); 4] = [
        ("exact", Variant::Exact),
        ("dst", Variant::Dst { band: 1 }),
        (
            "tlr",
            Variant::Tlr {
                tol: 1e-7,
                max_rank: usize::MAX,
            },
        ),
        ("mp", Variant::Mp { band: 1 }),
    ];
    for (name, variant) in variants {
        let legacy = match variant {
            Variant::Exact => exa.exact_mle(&data, "ugsm-s", "euclidean", &opt),
            Variant::Dst { band } => exa.dst_mle(&data, "ugsm-s", "euclidean", &opt, band),
            Variant::Tlr { tol, max_rank } => {
                exa.tlr_mle(&data, "ugsm-s", "euclidean", &opt, tol, max_rank)
            }
            Variant::Mp { band } => exa.mp_mle(&data, "ugsm-s", "euclidean", &opt, band),
        }
        .unwrap();

        let model = GeoModel::builder()
            .data(data.clone())
            .kernel("ugsm-s")
            .metric("euclidean")
            .variant(variant)
            .options(opt.clone())
            .tile_size(32)
            .build()
            .unwrap();
        let ticket = client.submit(Request::mle_from_model(&model, 0));
        let Completion::Done(resp) = ticket.wait() else {
            panic!("{name}: client route did not complete");
        };
        let Outcome::Mle(m) = resp.outcome else {
            panic!("{name}: wrong outcome");
        };
        assert_eq!(
            legacy.loglik.to_bits(),
            m.loglik.to_bits(),
            "{name}: loglik {} vs {}",
            legacy.loglik,
            m.loglik
        );
        assert_eq!(legacy.iters, m.iters, "{name}: iteration count");
        assert_bits_eq(&legacy.theta, &m.theta, name);
    }
    client.shutdown();
    coord.shutdown();
    exa.finalize();
}

#[test]
fn legacy_exact_predict_bit_matches_predict_at_route() {
    let exa = ExaGeoStat::init(hw(2, 32));
    let data = exa
        .simulate_data_exact("ugsm-s", &[1.0, 0.2, 1.0], "euclidean", 110, 7)
        .unwrap();
    let train = GeoData {
        locs: data.locs[..100].to_vec(),
        z: data.z[..100].to_vec(),
    };
    let target = data.locs[100..].to_vec();
    let theta = vec![1.0, 0.2, 1.0];
    let legacy = exa
        .exact_predict(&train, &target, "ugsm-s", "euclidean", &theta, true)
        .unwrap();

    let coord = Arc::new(Coordinator::new(hw(2, 32)));
    let client = Client::new(coord.clone(), 1);
    let model = GeoModel::builder()
        .data(train)
        .kernel("ugsm-s")
        .metric("euclidean")
        .build()
        .unwrap();
    let ticket = client.submit(Request::predict_at(
        &model,
        target.clone(),
        theta.clone(),
        true,
        0,
    ));
    let Completion::Done(resp) = ticket.wait() else {
        panic!("predict_at did not complete");
    };
    let Outcome::Prediction(p) = resp.outcome else {
        panic!("wrong outcome kind {:?}", resp.kind);
    };
    assert_bits_eq(&legacy.mean, &p.mean, "kriging mean");
    let (lv, cv) = (legacy.variance.unwrap(), p.variance.unwrap());
    assert_bits_eq(&lv, &cv, "kriging variance");
    client.shutdown();
    coord.shutdown();
    exa.finalize();
}

fn mle_request(n: usize, seed: u64, max_iters: usize) -> Request {
    let mut req = exageostat::coordinator::parse_request(&format!(
        "{{\"type\":\"mle\",\"n\":{n},\"seed\":{seed},\"max_iters\":{max_iters},\
         \"clb\":[0.01,0.01,0.01],\"tol\":1e-9}}"
    ))
    .unwrap();
    req.priority = 0;
    req
}

#[test]
fn cancelled_job_runs_fewer_tasks_and_wait_reports_cancelled() {
    let n = 400;
    let iters = 80;
    // Baseline: the same request run to completion on a fresh stack.
    let full_tasks = {
        let coord = Arc::new(Coordinator::new(hw(2, 32)));
        let client = Client::new(coord.clone(), 1);
        let t = client.submit(mle_request(n, 1, iters));
        assert!(matches!(t.wait(), Completion::Done(_)));
        let tasks = coord.runtime().tasks_executed();
        client.shutdown();
        coord.shutdown();
        tasks
    };
    assert!(full_tasks > 0);

    // Cancelled: identical request, token fired ~120ms in (an n=400
    // 80-iteration exact MLE takes far longer than that).
    let coord = Arc::new(Coordinator::new(hw(2, 32)));
    let client = Client::new(coord.clone(), 1);
    let t = client.submit(mle_request(n, 1, iters));
    std::thread::sleep(Duration::from_millis(120));
    t.cancel();
    match t.wait() {
        Completion::Cancelled => {}
        other => panic!("expected Cancelled, got {other:?}"),
    }
    let cancelled_tasks = coord.runtime().tasks_executed();
    assert!(
        cancelled_tasks < full_tasks,
        "cancelled run executed {cancelled_tasks} tasks, completed run {full_tasks}"
    );
    let st = coord.stats();
    assert_eq!(st.cancelled, 1, "{st:?}");
    assert_eq!(st.errors, 0, "{st:?}");

    // The coordinator stays healthy: the same request completes
    // afterwards (rebinding the cached session to a fresh token).
    let t2 = client.submit(mle_request(n, 1, iters));
    assert!(matches!(t2.wait(), Completion::Done(_)));
    client.shutdown();
    coord.shutdown();
}

#[test]
fn precancelled_session_reports_cancelled_without_work() {
    // Deterministic cancellation path: the token is already fired when
    // the MLE starts, so zero objective evaluations (and zero runtime
    // tasks) happen and the typed error surfaces.
    use exageostat::api::mle_with_session;
    use exageostat::covariance::{kernel_by_name, DistanceMetric};
    use exageostat::likelihood::{EvalSession, ExecCtx, Problem};
    use exageostat::rng::Pcg64;
    use exageostat::scheduler::runtime::CancelToken;

    let mut rng = Pcg64::seed_from_u64(11);
    let problem = Problem {
        kernel: kernel_by_name("ugsm-s").unwrap().into(),
        locs: Arc::new(exageostat::testkit::gen::locations(&mut rng, 40)),
        z: Arc::new(exageostat::testkit::gen::normals(&mut rng, 40)),
        metric: DistanceMetric::Euclidean,
    };
    let ctx = ExecCtx::new(1, 16, Policy::Eager);
    let mut session = EvalSession::new(&problem, Variant::Exact, &ctx).unwrap();
    let token = CancelToken::new();
    token.cancel();
    session.set_cancel(token);
    let tasks_before = ctx.runtime.tasks_executed();
    let err = mle_with_session(
        &mut session,
        &MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-4, 20),
    )
    .unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ApiError>(), Some(ApiError::Cancelled)),
        "{err:#}"
    );
    assert_eq!(session.evals(), 0, "no objective evaluation may run");
    assert_eq!(ctx.runtime.tasks_executed(), tasks_before);
}

/// Regression: a cancel that lands only *after* a job completed must
/// not rewrite its outcome or bump the cancelled counter.  The outcome
/// is decided once, at completion, by the layers that can actually
/// observe an interruption (skipped runtime tasks, an optimizer that
/// latched its stop signal) — never by re-reading the token afterwards,
/// which races against exactly this late-cancel pattern.
#[test]
fn cancel_after_completion_keeps_done_and_stats_clean() {
    let coord = Arc::new(Coordinator::new(hw(2, 32)));
    let client = Client::new(coord.clone(), 1);
    let t = client.submit(mle_request(60, 5, 4));
    assert!(matches!(t.wait(), Completion::Done(_)));
    // The job is fully drained; now fire its token.
    t.cancel();
    assert!(t.is_cancelled());
    assert!(
        matches!(t.wait(), Completion::Done(_)),
        "late cancel rewrote a completed outcome"
    );
    let st = coord.stats();
    assert_eq!(st.cancelled, 0, "late cancel was counted: {st:?}");
    assert_eq!(st.errors, 0, "{st:?}");
    client.shutdown();
    coord.shutdown();
}

/// Regression companion: a token fired *before* the request starts is
/// a real cancellation — typed `ApiError::Cancelled`, counted exactly
/// once in `stats().cancelled` (not as an error), and nothing half-done
/// lands in the dataset cache.
#[test]
fn prefired_token_reports_typed_cancelled_and_caches_nothing() {
    use exageostat::scheduler::runtime::CancelToken;
    let coord = Coordinator::new(hw(1, 32));
    let sim = |seed: u64| {
        exageostat::coordinator::parse_request(&format!(
            "{{\"type\":\"simulate\",\"n\":80,\"seed\":{seed}}}"
        ))
        .unwrap()
    };
    let token = CancelToken::new();
    token.cancel();
    let err = coord.run_with_cancel(sim(4), &token).unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ApiError>(), Some(ApiError::Cancelled)),
        "{err:#}"
    );
    let st = coord.stats();
    assert_eq!(st.cancelled, 1, "{st:?}");
    assert_eq!(st.errors, 0, "cancellation miscounted as error: {st:?}");
    // The cancelled request must not have populated the dataset cache.
    let resp = coord.run(sim(4)).unwrap();
    assert!(!resp.data_cache_hit, "cancelled request leaked into the cache");
    coord.shutdown();
}

#[test]
fn failed_mid_mle_job_evicts_partial_cache_state() {
    use exageostat::scheduler::runtime::TaskError;
    use exageostat::testkit::{
        fault_test_lock, set_fault_plan, set_job_retry_override, set_task_retry_override,
        FaultPlan,
    };
    // The fault plan and retry overrides are process-global.
    let _serial = fault_test_lock();
    let coord = Coordinator::new(hw(1, 32));
    let sim = || {
        exageostat::coordinator::parse_request("{\"type\":\"simulate\",\"n\":96,\"seed\":7}")
            .unwrap()
    };
    // Warm the dataset cache fault-free and prove it is warm: the MLE
    // below shares this request's DataSpec key.
    coord.run(sim()).unwrap();
    assert!(coord.run(sim()).unwrap().data_cache_hit, "warm-up failed");
    // Every task draw panics and no retry budget exists anywhere, so the
    // MLE dies mid-flight, on its first session-build task.
    set_task_retry_override(Some(0));
    set_job_retry_override(Some(0));
    set_fault_plan(Some(FaultPlan {
        panic_rate: 1.0,
        ..FaultPlan::default()
    }));
    let err = coord.run(mle_request(96, 7, 5)).unwrap_err();
    set_fault_plan(None);
    set_task_retry_override(None);
    set_job_retry_override(None);
    assert!(
        err.chain()
            .any(|c| matches!(c.downcast_ref::<TaskError>(), Some(TaskError::Panic(_)))),
        "expected a typed task panic, got: {err:#}"
    );
    let st = coord.stats();
    assert_eq!(st.errors, 1, "{st:?}");
    // The failure must have evicted the request's cached state — the
    // previously warm dataset entry included — so a disarmed rerun
    // rebuilds everything from scratch (no cache hits) and succeeds.
    let resp = coord.run(mle_request(96, 7, 5)).unwrap();
    assert!(
        !resp.data_cache_hit,
        "failed job left its dataset in the cache"
    );
    assert!(
        !resp.session_cache_hit,
        "failed job left a session in the cache"
    );
    coord.shutdown();
}

#[test]
fn band_too_large_rejected_by_wrapper_and_parse_route_still_works() {
    let exa = ExaGeoStat::init(hw(1, 32));
    let data = exa
        .simulate_data_exact("ugsm-s", &[1.0, 0.1, 0.5], "euclidean", 64, 2)
        .unwrap();
    // 64 points at ts=32 -> 2x2 tile grid: band 2 covers everything.
    let opt = MleOptions::new(vec![0.01; 3], vec![5.0; 3], 1e-3, 5);
    let err = exa
        .dst_mle(&data, "ugsm-s", "euclidean", &opt, 2)
        .unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ApiError>(),
            Some(ApiError::BandTooLarge { band: 2, ntiles: 2 })
        ),
        "{err:#}"
    );
    // band 1 (= full off-diagonal coverage on a 2x2 grid) still works
    assert!(exa.dst_mle(&data, "ugsm-s", "euclidean", &opt, 1).is_ok());
    exa.finalize();
}
