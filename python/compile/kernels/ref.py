"""Pure-jnp oracle for the Pallas Matérn kernel (the correctness contract
of the L1 layer — pytest asserts `matern.*` against these).

Deliberately written in the most direct form possible (explicit pairwise
distances, no MXU decomposition) so a bug in the kernel's algebra cannot
be mirrored here.
"""

import jax.numpy as jnp


def matern_correlation(t, nu):
    """Half-integer Matérn correlation from scaled distance t = d / beta.

    Matches the paper's parametrization (Eq. 3 with sigma_sq = 1):
    nu = 0.5 -> exp(-t); 1.5 -> (1+t)exp(-t); 2.5 -> (1+t+t^2/3)exp(-t).
    """
    e = jnp.exp(-t)
    if nu < 1.0:
        return e
    if nu < 2.0:
        return (1.0 + t) * e
    return (1.0 + t + t * t / 3.0) * e


def matern_tile_ref(x1, x2, theta):
    """(ts, ts) covariance tile: direct O(ts^2) evaluation."""
    sigma_sq, beta, nu = float(theta[0]), float(theta[1]), float(theta[2])
    diff = x1[:, None, :] - x2[None, :, :]  # (ts, ts, 2)
    d = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    return sigma_sq * matern_correlation(d / beta, nu)


def cov_matrix_ref(locs, theta):
    """Full (n, n) covariance."""
    return matern_tile_ref(locs, locs, theta)


def loglik_ref(locs, z, theta, jitter=0.0):
    """Dense Gaussian log-likelihood oracle (Eq. 2, zero mean):
    -1/2 z' Sigma^{-1} z - 1/2 log|Sigma| - n/2 log(2 pi).
    """
    n = locs.shape[0]
    sigma = cov_matrix_ref(locs, theta) + jitter * jnp.eye(n, dtype=locs.dtype)
    chol = jnp.linalg.cholesky(sigma)
    y = jnp.linalg.solve(chol, z)
    sse = jnp.sum(y * y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(chol)))
    return -0.5 * sse - 0.5 * logdet - 0.5 * n * jnp.log(2.0 * jnp.pi)
