"""L1 — Pallas Matérn covariance tile kernel.

This is ExaGeoStat's `dcmg` hot-spot (covariance-matrix generation)
expressed as a Pallas kernel: given a block of `ts` row coordinates and a
block of `ts` column coordinates, produce the `ts x ts` covariance tile

    C[i, j] = sigma_sq * M_nu(||s_i - s_j|| / beta)

with the Matérn correlation `M_nu` evaluated through its half-integer
closed forms (nu in {1/2, 3/2, 5/2} — the family the paper's experiments
use; general nu requires Bessel K_nu, which the Rust L3 path provides).
The branch is selected with `jnp.where`, so a single compiled artifact
serves all three smoothness classes.

TPU mapping (DESIGN.md §Hardware-Adaptation): pairwise distances use the
direct-difference form (numerically exact near d = 0 — see the kernel
body comment; the MXU Gram-decomposition alternative trades accuracy);
the transcendental tail (exp) runs on the VPU.
`interpret=True` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).

VMEM footprint per (ts=64) f64 tile: 2 * 64*2 * 8 B (coords) +
64*64 * 8 B (out) + intermediates ~ 3 * 32 KiB << 16 MiB, so tiles up to
ts = 512 stay VMEM-resident; the AOT recipe emits ts in {32, 64}.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["matern_tile", "matern_cov_matrix"]


def _matern_from_t(t):
    """Half-integer Matérn correlations from scaled distance t = d / beta.

    Returns the three closed forms; selection happens in the caller so the
    `where` runs once on the final values (cheap, branch-free).
    """
    e = jnp.exp(-t)
    m05 = e
    m15 = (1.0 + t) * e
    m25 = (1.0 + t + t * t / 3.0) * e
    return m05, m15, m25


def _matern_kernel(x1_ref, x2_ref, theta_ref, out_ref):
    """Pallas kernel body: one covariance tile.

    x1_ref: (ts, 2) row coordinates;  x2_ref: (ts, 2) column coordinates;
    theta_ref: (3,) = (sigma_sq, beta, nu);  out_ref: (ts, ts).
    """
    x1 = x1_ref[...]
    x2 = x2_ref[...]
    sigma_sq = theta_ref[0]
    beta = theta_ref[1]
    nu = theta_ref[2]

    # Pairwise distances via direct differences.  The MXU-friendly Gram
    # decomposition (||a||^2 + ||b||^2 - 2 a.b) is ~2x faster on TPU but
    # loses ~sqrt(eps) of absolute distance accuracy to cancellation for
    # near-coincident points, which a covariance kernel cannot afford
    # (diagonal entries define the nugget behaviour).  d = 2 here, so the
    # direct form is only a (ts, ts, 2) broadcast — still VMEM-resident.
    dx = x1[:, None, 0] - x2[None, :, 0]
    dy = x1[:, None, 1] - x2[None, :, 1]
    t = jnp.sqrt(dx * dx + dy * dy) / beta

    m05, m15, m25 = _matern_from_t(t)
    corr = jnp.where(nu < 1.0, m05, jnp.where(nu < 2.0, m15, m25))
    out_ref[...] = sigma_sq * corr


def matern_tile(x1, x2, theta, *, interpret=True):
    """One covariance tile via `pallas_call`.

    x1: (ts, 2), x2: (ts, 2), theta: (3,) -> (ts, ts).
    """
    ts = x1.shape[0]
    assert x1.shape == x2.shape == (ts, 2), (x1.shape, x2.shape)
    dtype = x1.dtype
    return pl.pallas_call(
        _matern_kernel,
        out_shape=jax.ShapeDtypeStruct((ts, ts), dtype),
        interpret=interpret,
    )(x1, x2, theta.astype(dtype))


def matern_cov_matrix(locs, theta, *, ts=64, interpret=True):
    """Full (n, n) covariance assembled tile-by-tile with a Pallas grid.

    `locs` is (n, 2) with n a multiple of `ts` (the AOT entry points pick
    compatible shapes).  The BlockSpec index maps express the HBM->VMEM
    tile schedule: grid cell (i, j) streams row block i and column block j.
    """
    n = locs.shape[0]
    assert n % ts == 0, f"n={n} must be a multiple of ts={ts}"
    grid = (n // ts, n // ts)
    dtype = locs.dtype
    return pl.pallas_call(
        _matern_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ts, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((ts, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((ts, ts), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), dtype),
        interpret=interpret,
    )(locs, locs, theta.astype(dtype))


# Convenience jitted entry used by the AOT recipe.
matern_tile_jit = jax.jit(partial(matern_tile, interpret=True))
