"""AOT recipe: lower the L2/L1 computations to HLO *text* artifacts the
Rust runtime loads through PJRT.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all float64; shapes static per PJRT requirements):
  matern_tile_ts<TS>.hlo.txt   (x1 (TS,2), x2 (TS,2), theta (3,)) -> (TS,TS)
  loglik_n<N>.hlo.txt          (locs (N,2), z (N,), theta (3,))
                               -> (loglik, logdet, sse) scalars
  manifest.txt                 one line per artifact: name shape-signature

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

TILE_SIZES = (32, 64)
LOGLIK_SIZES = (256,)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_matern_tile(ts: int) -> str:
    spec2 = jax.ShapeDtypeStruct((ts, 2), jnp.float64)
    spec_theta = jax.ShapeDtypeStruct((3,), jnp.float64)
    lowered = jax.jit(lambda x1, x2, t: (model.matern_tile_entry(x1, x2, t),)).lower(
        spec2, spec2, spec_theta
    )
    return to_hlo_text(lowered)


def lower_loglik(n: int, ts: int = 64) -> str:
    locs = jax.ShapeDtypeStruct((n, 2), jnp.float64)
    z = jax.ShapeDtypeStruct((n,), jnp.float64)
    theta = jax.ShapeDtypeStruct((3,), jnp.float64)
    lowered = jax.jit(lambda l, zz, t: model.loglik_parts(l, zz, t, ts=ts)).lower(
        locs, z, theta
    )
    return to_hlo_text(lowered)


def build_all(out_dir: str) -> list[tuple[str, str]]:
    """Lower every artifact; returns (filename, signature) pairs."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for ts in TILE_SIZES:
        name = f"matern_tile_ts{ts}.hlo.txt"
        text = lower_matern_tile(ts)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        entries.append((name, f"(f64[{ts},2], f64[{ts},2], f64[3]) -> f64[{ts},{ts}]"))
        print(f"wrote {name} ({len(text)} chars)")
    for n in LOGLIK_SIZES:
        name = f"loglik_n{n}.hlo.txt"
        ts = max(t for t in (16, 32, 64) if n % t == 0)
        text = lower_loglik(n, ts=ts)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        entries.append((name, f"(f64[{n},2], f64[{n}], f64[3]) -> (f64, f64, f64)"))
        print(f"wrote {name} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for name, sig in entries:
            f.write(f"{name}\t{sig}\n")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
