"""L2 — the JAX compute graph of the exact Gaussian log-likelihood (Eq. 2),
built on the L1 Pallas covariance kernel.

`loglik(locs, z, theta)` is the function the paper's MLE evaluates at each
BOBYQA iteration: covariance generation (Pallas tiles) -> Cholesky ->
triangular solve -> log-determinant + quadratic form.  `aot.py` lowers it
once per problem size to HLO text; the Rust coordinator then executes the
artifact through PJRT with Python entirely off the request path.
"""

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from .kernels.matern import matern_cov_matrix, matern_tile

__all__ = ["loglik", "loglik_parts", "matern_tile_entry"]


def cholesky_hlo(a):
    """Lower Cholesky written in plain jnp ops (fori_loop + matvec).

    `jnp.linalg.cholesky` lowers to a typed-FFI LAPACK custom-call that the
    runtime's xla_extension 0.5.1 cannot execute; this column-by-column
    formulation lowers to a plain HLO while-loop, which round-trips through
    HLO text cleanly.  O(n^3) total with O(n^2) work per loop step.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, chol):
        # v = a[:, j] - sum_{k<j} chol[:, k] * chol[j, k]
        lj_row = jnp.where(idx < j, chol[j, :], 0.0)
        v = a[:, j] - chol @ lj_row
        d = jnp.sqrt(v[j])
        col = jnp.where(idx >= j, v / d, 0.0)
        return chol.at[:, j].set(col)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def forward_solve_hlo(chol, z):
    """`y = L^{-1} z` by forward substitution in plain jnp ops."""
    n = z.shape[0]
    idx = jnp.arange(n)

    def body(j, y):
        lj_row = jnp.where(idx < j, chol[j, :], 0.0)
        yj = (z[j] - jnp.dot(lj_row, y)) / chol[j, j]
        return y.at[j].set(yj)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(z))

# A hair of diagonal jitter keeps AOT artifacts usable across the whole
# bound box the optimizer explores (near-duplicate locations at tiny beta
# would otherwise make Cholesky produce NaNs).
JITTER = 1e-10


def loglik_parts(locs, z, theta, *, ts=64):
    """Return (loglik, logdet, sse) — the three scalars the Rust side logs.

    locs: (n, 2); z: (n,); theta: (3,) = (sigma_sq, beta, nu).
    """
    n = locs.shape[0]
    sigma = matern_cov_matrix(locs, theta, ts=ts)
    sigma = sigma + JITTER * jnp.eye(n, dtype=sigma.dtype)
    chol = cholesky_hlo(sigma)
    y = forward_solve_hlo(chol, z)
    sse = jnp.sum(y * y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(chol)))
    ll = -0.5 * sse - 0.5 * logdet - 0.5 * n * jnp.log(2.0 * jnp.pi)
    return ll, logdet, sse


def loglik(locs, z, theta, *, ts=64):
    """Scalar log-likelihood (the optimizer objective)."""
    return loglik_parts(locs, z, theta, ts=ts)[0]


def matern_tile_entry(x1, x2, theta):
    """Standalone tile entry point (the `dcmg` task body) for AOT export."""
    return matern_tile(x1, x2, theta)


def loglik_differentiable(locs, z, theta):
    """Gradient-capable log-likelihood (fwd + bwd).

    Pallas `interpret=True` kernels do not define a VJP, so the
    differentiable variant builds the covariance with plain jnp (the same
    math as `kernels/ref.py`).  The BOBYQA MLE is derivative-free and uses
    the Pallas path; this entry exists for gradient-based workflows and
    for the Fisher-information cross-checks.
    """
    n = locs.shape[0]
    sigma_sq, beta, nu = theta[0], theta[1], theta[2]
    diff = locs[:, None, :] - locs[None, :, :]
    d = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-300)
    t = d / beta
    e = jnp.exp(-t)
    corr = jnp.where(
        nu < 1.0, e, jnp.where(nu < 2.0, (1.0 + t) * e, (1.0 + t + t * t / 3.0) * e)
    )
    sigma = sigma_sq * corr + (JITTER + sigma_sq * 0.0) * jnp.eye(n, dtype=locs.dtype)
    # restore exact diagonal (distance hack above perturbs it by ~1e-150)
    sigma = sigma.at[jnp.diag_indices(n)].set(sigma_sq + JITTER)
    chol = jnp.linalg.cholesky(sigma)
    y = solve_triangular(chol, z, lower=True)
    sse = jnp.sum(y * y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(chol)))
    return -0.5 * sse - 0.5 * logdet - 0.5 * n * jnp.log(2.0 * jnp.pi)
