"""L2 correctness: the JAX log-likelihood graph against a direct numpy
oracle, plus shape/grad sanity (the fwd/bwd contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model
from compile.kernels import ref


def make_problem(n, seed, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    locs = jnp.asarray(rng.uniform(0.0, 1.0, size=(n, 2)), dtype=dtype)
    z = jnp.asarray(rng.standard_normal(n), dtype=dtype)
    return locs, z


@pytest.mark.parametrize("n,ts", [(64, 16), (128, 32), (256, 64)])
def test_loglik_matches_oracle(n, ts):
    locs, z = make_problem(n, n)
    theta = jnp.array([1.0, 0.1, 0.5], dtype=jnp.float64)
    ll, logdet, sse = model.loglik_parts(locs, z, theta, ts=ts)
    want = ref.loglik_ref(locs, z, theta, jitter=model.JITTER)
    # Cholesky of a moderately conditioned matrix assembled in different
    # tile orders: agree to ~1e-6 relative.
    np.testing.assert_allclose(float(ll), float(want), rtol=1e-6)
    # parts identity: ll = -0.5 sse - 0.5 logdet - n/2 log(2 pi)
    recon = -0.5 * float(sse) - 0.5 * float(logdet) - 0.5 * n * np.log(2 * np.pi)
    np.testing.assert_allclose(float(ll), recon, rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(
    nu=st.sampled_from([0.5, 1.5, 2.5]),
    beta=st.floats(0.05, 0.5),
    sigma_sq=st.floats(0.3, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_loglik_hypothesis_sweep(nu, beta, sigma_sq, seed):
    locs, z = make_problem(64, seed)
    theta = jnp.array([sigma_sq, beta, nu], dtype=jnp.float64)
    ll = model.loglik(locs, z, theta, ts=16)
    want = ref.loglik_ref(locs, z, theta, jitter=model.JITTER)
    np.testing.assert_allclose(float(ll), float(want), rtol=1e-6)


def test_loglik_grad_exists_and_is_finite():
    """The differentiable L2 variant must provide fwd + bwd.

    (Pallas interpret kernels define no VJP; `loglik_differentiable` is
    the gradient path — see its docstring.)
    """
    locs, z = make_problem(64, 11)
    theta = jnp.array([1.0, 0.1, 0.5], dtype=jnp.float64)
    f = lambda t: model.loglik_differentiable(locs, z, t)  # noqa: E731
    # value agrees with the pallas path
    np.testing.assert_allclose(
        float(f(theta)), float(model.loglik(locs, z, theta, ts=16)), rtol=1e-6
    )
    g = jax.grad(f)(theta)
    assert g.shape == (3,)
    assert np.isfinite(np.asarray(g)).all()
    # finite-difference check on sigma_sq and beta
    for i in [0, 1]:
        h = 1e-6
        tp = theta.at[i].add(h)
        tm = theta.at[i].add(-h)
        fd = (f(tp) - f(tm)) / (2 * h)
        np.testing.assert_allclose(float(g[i]), float(fd), rtol=1e-4)


def test_loglik_peaks_near_truth_in_sigma():
    """Profile check: with data drawn at sigma_sq=2, the likelihood at
    sigma_sq=2 beats sigma_sq in {0.5, 8}."""
    rng = np.random.default_rng(13)
    n = 128
    locs = jnp.asarray(rng.uniform(0, 1, size=(n, 2)), dtype=jnp.float64)
    theta_true = jnp.array([2.0, 0.1, 0.5], dtype=jnp.float64)
    sigma = ref.cov_matrix_ref(locs, theta_true) + 1e-10 * jnp.eye(n)
    chol = np.linalg.cholesky(np.asarray(sigma))
    z = jnp.asarray(chol @ rng.standard_normal(n), dtype=jnp.float64)
    lls = {
        s: float(model.loglik(locs, z, jnp.array([s, 0.1, 0.5]), ts=32))
        for s in [0.5, 2.0, 8.0]
    }
    assert lls[2.0] > lls[0.5] and lls[2.0] > lls[8.0], lls
