"""Enable x64 before any test imports jax-dependent modules: the AOT
artifacts are float64 (Rust's linalg substrate is f64 throughout)."""

import jax

jax.config.update("jax_enable_x64", True)
