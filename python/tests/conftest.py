"""Enable x64 before any test imports jax-dependent modules: the AOT
artifacts are float64 (Rust's linalg substrate is f64 throughout).

Guarded so that collection on a JAX-less machine skips this suite
instead of crashing the whole pytest run (e.g. when the directory is
targeted directly, bypassing the repo-root conftest's ignore)."""

try:
    import jax
except ImportError:
    collect_ignore_glob = ["*"]
else:
    jax.config.update("jax_enable_x64", True)
