"""AOT recipe tests: lowering produces parseable HLO text with the right
entry signature (the Rust runtime's `HloModuleProto::from_text_file`
contract)."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_matern_tile_lowers_to_hlo_text():
    text = aot.lower_matern_tile(8)
    assert text.startswith("HloModule")
    # three f64 parameters with the right shapes
    assert "f64[8,2]" in text
    assert "f64[3]" in text
    assert "f64[8,8]" in text


def test_loglik_lowers_to_hlo_text():
    text = aot.lower_loglik(64, ts=16)
    assert text.startswith("HloModule")
    assert "f64[64,2]" in text
    # cholesky decomposes into HLO (loops/ops), output is a 3-tuple of scalars
    assert "(f64[], f64[], f64[])" in text.replace("f64[] ", "f64[]").replace(
        ", ", ", "
    ) or text.count("f64[]") >= 3


def test_build_all_writes_manifest(tmp_path):
    # monkey-patch smaller sizes to keep the test fast
    old_tiles, old_lls = aot.TILE_SIZES, aot.LOGLIK_SIZES
    aot.TILE_SIZES, aot.LOGLIK_SIZES = (8,), (32,)
    try:
        entries = aot.build_all(str(tmp_path))
    finally:
        aot.TILE_SIZES, aot.LOGLIK_SIZES = old_tiles, old_lls
    names = {e[0] for e in entries}
    assert names == {"matern_tile_ts8.hlo.txt", "loglik_n32.hlo.txt"}
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "matern_tile_ts8.hlo.txt" in manifest
    for name in names:
        assert (tmp_path / name).read_text().startswith("HloModule")


def test_lowered_loglik_executes_same_value():
    """Round-trip: the jitted function and the eager model agree (the
    artifact the Rust side loads computes this exact jitted graph)."""
    import jax

    rng = np.random.default_rng(21)
    locs = jnp.asarray(rng.uniform(0, 1, size=(32, 2)), dtype=jnp.float64)
    z = jnp.asarray(rng.standard_normal(32), dtype=jnp.float64)
    theta = jnp.array([1.0, 0.1, 0.5], dtype=jnp.float64)
    jitted = jax.jit(lambda l, zz, t: model.loglik_parts(l, zz, t, ts=16))
    got = jitted(locs, z, theta)
    want = model.loglik_parts(locs, z, theta, ts=16)
    for g, w in zip(got, want):
        np.testing.assert_allclose(float(g), float(w), rtol=1e-12)
