"""L1 correctness: the Pallas Matérn tile kernel against the pure-jnp
oracle (`ref.py`) — the core correctness signal of the compile path.

Hypothesis sweeps shapes, dtypes, smoothness classes and parameter ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref
from compile.kernels.matern import matern_cov_matrix, matern_tile

NUS = [0.5, 1.5, 2.5]


def rand_coords(rng, ts, dtype):
    return jnp.asarray(rng.uniform(0.0, 1.0, size=(ts, 2)), dtype=dtype)


@pytest.mark.parametrize("ts", [4, 8, 16, 32, 64])
@pytest.mark.parametrize("nu", NUS)
def test_tile_matches_ref_f64(ts, nu):
    rng = np.random.default_rng(ts * 1000 + int(nu * 10))
    x1 = rand_coords(rng, ts, jnp.float64)
    x2 = rand_coords(rng, ts, jnp.float64)
    theta = jnp.array([1.3, 0.17, nu], dtype=jnp.float64)
    got = matern_tile(x1, x2, theta)
    want = ref.matern_tile_ref(x1, x2, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-12)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.float64, 1e-11)])
def test_tile_dtypes(dtype, tol):
    rng = np.random.default_rng(7)
    x1 = rand_coords(rng, 16, dtype)
    x2 = rand_coords(rng, 16, dtype)
    theta = jnp.array([2.0, 0.1, 0.5], dtype=dtype)
    got = matern_tile(x1, x2, theta)
    want = ref.matern_tile_ref(x1, x2, theta)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@settings(max_examples=40, deadline=None)
@given(
    ts=st.sampled_from([4, 8, 16]),
    sigma_sq=st.floats(0.1, 10.0),
    beta=st.floats(0.02, 1.0),
    nu=st.sampled_from(NUS),
    seed=st.integers(0, 2**31 - 1),
)
def test_tile_matches_ref_hypothesis(ts, sigma_sq, beta, nu, seed):
    rng = np.random.default_rng(seed)
    x1 = rand_coords(rng, ts, jnp.float64)
    x2 = rand_coords(rng, ts, jnp.float64)
    theta = jnp.array([sigma_sq, beta, nu], dtype=jnp.float64)
    got = matern_tile(x1, x2, theta)
    want = ref.matern_tile_ref(x1, x2, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-11)


def test_diagonal_tile_properties():
    """Same coordinate block on both sides: symmetric, sigma_sq diagonal."""
    rng = np.random.default_rng(3)
    x = rand_coords(rng, 32, jnp.float64)
    theta = jnp.array([1.7, 0.2, 1.5], dtype=jnp.float64)
    tile = np.asarray(matern_tile(x, x, theta))
    np.testing.assert_allclose(np.diag(tile), 1.7, rtol=1e-12)
    np.testing.assert_allclose(tile, tile.T, rtol=1e-12, atol=1e-13)
    assert (tile > 0).all() and (tile <= 1.7 + 1e-12).all()


def test_nu_branch_selection():
    """The where-chain must pick the right closed form per nu class."""
    rng = np.random.default_rng(4)
    x1 = rand_coords(rng, 8, jnp.float64)
    x2 = rand_coords(rng, 8, jnp.float64)
    outs = []
    for nu in NUS:
        theta = jnp.array([1.0, 0.1, nu], dtype=jnp.float64)
        outs.append(np.asarray(matern_tile(x1, x2, theta)))
    # smoother kernels give strictly higher correlation off-diagonal
    assert (outs[0] < outs[1]).all()
    assert (outs[1] < outs[2]).all()


@pytest.mark.parametrize("n,ts", [(64, 16), (128, 32), (128, 64)])
def test_grid_cov_matrix_matches_ref(n, ts):
    """The gridded pallas_call (BlockSpec schedule) assembles the same
    matrix as the direct oracle."""
    rng = np.random.default_rng(n + ts)
    locs = rand_coords(rng, n, jnp.float64)
    theta = jnp.array([1.0, 0.1, 0.5], dtype=jnp.float64)
    got = matern_cov_matrix(locs, theta, ts=ts)
    want = ref.cov_matrix_ref(locs, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-12)


def test_jit_compatible():
    """The kernel must lower under jit (the AOT requirement)."""
    rng = np.random.default_rng(5)
    x = rand_coords(rng, 16, jnp.float64)
    theta = jnp.array([1.0, 0.1, 0.5], dtype=jnp.float64)
    f = jax.jit(lambda a, b, t: matern_tile(a, b, t))
    got = f(x, x, theta)
    want = ref.matern_tile_ref(x, x, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-12)
