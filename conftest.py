"""Repo-root pytest shim: make `pytest python/tests/` work from the root
by putting `python/` (the `compile` package parent) on sys.path and
enabling x64 before any jax-importing test module loads.

Machines without JAX (e.g. the Rust-only CI runners) must still be able
to run `pytest` without the collection itself crashing: in that case the
python suite is skipped wholesale instead of erroring the run."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

try:
    import jax
except ImportError:
    # No JAX on this machine: ignore the python suite entirely (the Rust
    # tier-1 suite carries the coverage; CI gates the pytest job on JAX).
    collect_ignore_glob = ["python/*"]
else:
    jax.config.update("jax_enable_x64", True)
