"""Repo-root pytest shim: make `pytest python/tests/` work from the root
by putting `python/` (the `compile` package parent) on sys.path and
enabling x64 before any jax-importing test module loads."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

import jax

jax.config.update("jax_enable_x64", True)
