#!/usr/bin/env python3
"""Bench regression gate (CI `bench-gate` job).

Compares the BENCH_*.json telemetry emitted by `make bench-smoke`
against the committed baseline (`ci/bench_baseline.json`) and fails on
regressions beyond the tolerance.

Baseline schema::

    {
      "tolerance": 0.25,
      "files": {
        "BENCH_kernels.json": [
          {"path": "mle.exact_eval_dispatch_s", "kind": "time",
           "value": 2.0, "note": "..."},
          {"path": "kernels[op=gemm,prec=f64,b=128].gflops_dispatch",
           "kind": "throughput", "value": 0.4}
        ]
      }
    }

* ``kind: "time"`` — lower is better; regression when
  ``current > value * (1 + tolerance)``.
* ``kind: "throughput"`` — higher is better (GFLOP/s, speedup ratios);
  regression when ``current < value * (1 - tolerance)``.
* A metric may carry its own ``tolerance`` overriding the global one.

Paths are dotted keys with optional list selectors:
``kernels[op=gemm,prec=f64,b=128].gflops_dispatch`` selects the unique
element of the ``kernels`` array whose fields match every ``k=v`` pair
(compared as strings).  A missing path or a ``null`` value is a skip
with a warning, not a failure: benches null out non-finite samples
(see ``jnum`` in the bench sources), and a flaky sample must not wedge
CI.  A missing *file* is a hard failure — the gate exists to ensure
the benches keep emitting their telemetry.

Usage: check_bench_regression.py [--baseline ci/bench_baseline.json]
                                 [--dir .]
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

TOKEN = re.compile(r"^(\w+)(?:\[([^\]]*)\])?$")


def resolve(doc, path):
    """Walk ``doc`` along ``path``; returns the value or raises KeyError."""
    for tok in path.split("."):
        m = TOKEN.match(tok)
        if not m:
            raise KeyError(f"malformed path token {tok!r}")
        name, selector = m.group(1), m.group(2)
        if not isinstance(doc, dict) or name not in doc:
            raise KeyError(f"no key {name!r}")
        doc = doc[name]
        if selector is not None:
            if not isinstance(doc, list):
                raise KeyError(f"{name!r} is not a list")
            pairs = [kv.split("=", 1) for kv in selector.split(",")]
            matches = [
                el
                for el in doc
                if isinstance(el, dict)
                and all(str(el.get(k)) == v for k, v in pairs)
            ]
            if len(matches) != 1:
                raise KeyError(
                    f"selector [{selector}] matched {len(matches)} "
                    f"elements of {name!r} (want exactly 1)"
                )
            doc = matches[0]
    return doc


def check_metric(doc, metric, global_tol):
    """Returns (status, message); status in {'ok', 'skip', 'fail'}."""
    path = metric["path"]
    kind = metric["kind"]
    base = metric["value"]
    tol = metric.get("tolerance", global_tol)
    try:
        cur = resolve(doc, path)
    except KeyError as e:
        return "skip", f"{path}: not found ({e})"
    if cur is None:
        return "skip", f"{path}: null (non-finite sample) — skipped"
    if not isinstance(cur, (int, float)):
        return "fail", f"{path}: non-numeric value {cur!r}"
    if kind == "time":
        limit = base * (1.0 + tol)
        ok = cur <= limit
        detail = f"{cur:.4g}s vs baseline {base:.4g}s (limit {limit:.4g}s)"
    elif kind == "throughput":
        limit = base * (1.0 - tol)
        ok = cur >= limit
        detail = f"{cur:.4g} vs baseline {base:.4g} (floor {limit:.4g})"
    else:
        return "fail", f"{path}: unknown kind {kind!r}"
    return ("ok" if ok else "fail"), f"{path}: {detail}"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="ci/bench_baseline.json")
    ap.add_argument(
        "--dir", default=".", help="directory holding the BENCH_*.json files"
    )
    args = ap.parse_args(argv)

    baseline = json.loads(Path(args.baseline).read_text())
    global_tol = baseline.get("tolerance", 0.25)
    failures, skips, passes = [], [], []

    for fname, metrics in baseline["files"].items():
        fpath = Path(args.dir) / fname
        if not fpath.exists():
            failures.append(f"{fname}: file missing (bench did not emit it)")
            continue
        try:
            doc = json.loads(fpath.read_text())
        except json.JSONDecodeError as e:
            failures.append(f"{fname}: invalid JSON ({e})")
            continue
        for metric in metrics:
            status, msg = check_metric(doc, metric, global_tol)
            label = f"{fname} :: {msg}"
            if status == "fail":
                failures.append(label)
            elif status == "skip":
                skips.append(label)
            else:
                passes.append(label)

    for p in passes:
        print(f"  ok   {p}")
    for s in skips:
        print(f"  SKIP {s}")
    for f in failures:
        print(f"  FAIL {f}")
    print(
        f"bench gate: {len(passes)} ok, {len(skips)} skipped, "
        f"{len(failures)} failed (tolerance {global_tol:.0%})"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
