//! Perf probe: micro-benchmarks of the hot paths for the EXPERIMENTS.md
//! §Perf iteration log.  Not a paper figure; a tuning instrument — the
//! tracked, JSON-emitting equivalent is `rust/benches/kernel_roofline.rs`
//! (EXPERIMENTS.md §Kernel roofline).
use exageostat::covariance::{kernel_by_name, DistanceMetric};
use exageostat::likelihood::{ExecCtx, Problem, Variant};
use exageostat::linalg::blas::{dgemm_raw, dpotrf_raw, Trans};
use exageostat::rng::Pcg64;
use exageostat::scheduler::pool::Policy;
use std::time::Instant;

fn timeit(name: &str, flops: f64, reps: usize, mut f: impl FnMut()) {
    f();
    let t0 = Instant::now();
    for _ in 0..reps { f(); }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!("{name:<28} {:>9.3} ms  {:>7.2} GF/s", dt * 1e3, flops / dt / 1e9);
}

fn main() {
    let mut rng = Pcg64::seed_from_u64(1);
    for n in [256usize, 512] {
        let a: Vec<f64> = (0..n*n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n*n).map(|_| rng.normal()).collect();
        let mut c = vec![0.0; n*n];
        timeit(&format!("dgemm {n}"), 2.0*(n as f64).powi(3), 5, || {
            dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n);
        });
    }
    // potrf 1024
    let n = 1024;
    let b: Vec<f64> = (0..n*n).map(|_| rng.normal()).collect();
    let mut spd = vec![0.0; n*n];
    dgemm_raw(Trans::N, Trans::T, n, n, n, 1.0, &b, n, &b, n, 0.0, &mut spd, n);
    for i in 0..n { spd[i+i*n] += n as f64; }
    timeit("dpotrf 1024", (n as f64).powi(3)/3.0, 3, || {
        let mut m = spd.clone();
        dpotrf_raw(n, &mut m, n).unwrap();
    });
    // covariance generation cost, half-integer and general nu
    let kernel = kernel_by_name("ugsm-s").unwrap();
    let locs: Vec<_> = (0..1600).map(|_| exageostat::covariance::Location::new(rng.next_f64(), rng.next_f64())).collect();
    for (name, nu) in [("covgen nu=0.5 (closed)", 0.5), ("covgen nu=0.9 (bessel)", 0.9)] {
        let theta = [1.0, 0.1, nu];
        timeit(name, 0.0, 3, || {
            let mut out = vec![0.0; 1600*1600];
            exageostat::covariance::fill_cov_tile(kernel.as_ref(), &theta, &locs, DistanceMetric::Euclidean, 0, 0, 1600, 1600, &mut out);
            std::hint::black_box(&out);
        });
    }
    // full loglik n=1600
    let z: Vec<f64> = (0..1600).map(|_| rng.normal()).collect();
    let p = Problem { kernel: kernel_by_name("ugsm-s").unwrap().into(), locs: std::sync::Arc::new(locs), z: std::sync::Arc::new(z), metric: DistanceMetric::Euclidean };
    for ts in [100usize, 160, 320, 560] {
        let ctx = ExecCtx::new(1, ts, Policy::Prio);
        timeit(&format!("loglik n=1600 ts={ts}"), 0.0, 2, || {
            let _ = exageostat::likelihood::loglik(&p, &[1.0, 0.1, 0.9], Variant::Exact, &ctx).unwrap();
        });
    }
}
// appended: half-integer loglik ts sweep (perf pass round 2)
