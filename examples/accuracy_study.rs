//! Estimation-accuracy study — regenerates **Fig 4** (boxplots of
//! parameter estimates) and the iteration counts of **Table V** for the
//! paper's nine scenarios: beta in {0.03, 0.1, 0.3} x nu in {0.5, 1, 2},
//! sigma_sq = 1, comparing:
//!
//! * ExaGeoStatR (`exact_mle`, BOBYQA, estimates all three parameters)
//! * GeoR-like   (`likfit` analogue: Nelder–Mead, estimates mean + theta)
//! * fields-like (`MLESpatialProcess` analogue: BFGS, nu fixed at truth)
//!
//! The paper uses n = 1600 and 100 replicates; defaults here are scaled
//! for the testbed (`--n`, `--reps` to change).  Output: per-scenario
//! quartiles of each estimated parameter per package — the series the
//! boxplots plot.
//!
//! Run: `cargo run --release --example accuracy_study -- [--n 400] [--reps 10]`

use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::baselines::{fieldslike_mle, georlike_mle};
use exageostat::cli::Args;
use exageostat::covariance::DistanceMetric;
use exageostat::data::sst::quantile;
use exageostat::scheduler::pool::Policy;

struct Scenario {
    beta: f64,
    nu: f64,
}

fn summarize(name: &str, param: &str, vals: &mut Vec<f64>, truth: f64) {
    vals.sort_by(|a, b| a.total_cmp(b));
    println!(
        "  {name:<12} {param:<9} q25={:>7.3} med={:>7.3} q75={:>7.3}   (truth {truth})",
        quantile(vals, 0.25),
        quantile(vals, 0.5),
        quantile(vals, 0.75),
    );
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let n = args.get_usize("n", 400)?;
    let reps = args.get_usize("reps", 10)?;
    let tol = args.get_f64("tol", 1e-5)?;

    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ngpus: 0,
        ts: 100,
        pgrid: 1,
        qgrid: 1,
        policy: Policy::Prio,
    });

    let scenarios: Vec<Scenario> = [0.03, 0.1, 0.3]
        .iter()
        .flat_map(|&beta| [0.5, 1.0, 2.0].iter().map(move |&nu| Scenario { beta, nu }))
        .collect();

    println!("accuracy study: n={n}, reps={reps}, tol={tol} (paper: n=1600, reps=100)");
    println!("{}", "=".repeat(76));
    for sc in &scenarios {
        let theta_true = [1.0, sc.beta, sc.nu];
        println!("\nscenario beta={} nu={}", sc.beta, sc.nu);
        let mut est: Vec<Vec<Vec<f64>>> = vec![vec![Vec::new(); 3]; 3]; // pkg x param
        let mut iters = [0usize; 3];
        let mut tpi = [0.0f64; 3];
        for rep in 0..reps {
            let data =
                exa.simulate_data_exact("ugsm-s", &theta_true, "euclidean", n, 1 + rep as u64)?;
            // ExaGeoStatR
            let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], tol, 0);
            let r = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt)?;
            for p in 0..3 {
                est[0][p].push(r.theta[p]);
            }
            iters[0] += r.iters;
            tpi[0] += r.time_per_iter;
            // GeoR-like
            let g = georlike_mle(
                &data,
                DistanceMetric::Euclidean,
                &[0.001; 3],
                &[5.0; 3],
                tol,
                500,
            )?;
            for p in 0..3 {
                est[1][p].push(g.theta[p]);
            }
            iters[1] += g.iters;
            tpi[1] += g.time_per_iter;
            // fields-like (nu fixed at the truth — the paper's favour)
            let f = fieldslike_mle(
                &data,
                DistanceMetric::Euclidean,
                sc.nu,
                &[0.001; 2],
                &[5.0; 2],
                tol,
                500,
            )?;
            for p in 0..2 {
                est[2][p].push(f.theta[p]);
            }
            iters[2] += f.iters;
            tpi[2] += f.time_per_iter;
        }
        let pkgs = ["exageostat", "geor-like", "fields-like"];
        let params = ["sigma_sq", "beta", "nu"];
        for (k, pkg) in pkgs.iter().enumerate() {
            let nparams = if k == 2 { 2 } else { 3 };
            for p in 0..nparams {
                summarize(pkg, params[p], &mut est[k][p], theta_true[p]);
            }
            println!(
                "  {pkg:<12} avg iters = {:.0}, avg time/iter = {:.4} s",
                iters[k] as f64 / reps as f64,
                tpi[k] / reps as f64
            );
        }
    }
    exa.finalize();
    Ok(())
}
