//! Quickstart: the README example — simulate a Gaussian random field,
//! fit it by exact MLE, krige a held-out set, and (when the `pjrt`
//! feature is enabled and `make artifacts` has run) cross-check the
//! covariance tile and the likelihood against the AOT-compiled
//! JAX/Pallas artifacts through the PJRT backend.
//!
//! Run: `cargo run --release --example quickstart`

use exageostat::api::{ExaGeoStat, GeoModel, Hardware};
use exageostat::backend::{self, Backend, Engine as _};
use exageostat::covariance::{fill_cov_tile, kernel_by_name, DistanceMetric};
use exageostat::likelihood::Variant;
use exageostat::scheduler::pool::Policy;

fn main() -> anyhow::Result<()> {
    // 1. exageostat_init(hardware) — Example 1 of the paper.  The compute
    //    backend defaults to native; EXAGEOSTAT_BACKEND=pjrt overrides.
    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ngpus: 0,
        ts: 64,
        pgrid: 1,
        qgrid: 1,
        policy: Policy::Prio,
    });
    println!("backend: {}", exa.backend_name());

    // 2. simulate_data_exact: 400 locations, theta = (1, 0.1, 0.5).
    let theta_true = [1.0, 0.1, 0.5];
    let data = exa.simulate_data_exact("ugsm-s", &theta_true, "euclidean", 400, 0)?;
    println!("simulated n = {} (seed 0, theta = {theta_true:?})", data.n());

    // 3. Exact MLE with the paper's optimization settings, through the
    //    typed model builder (the legacy `exa.exact_mle(&data, "ugsm-s",
    //    "euclidean", &opt)` wrapper still works and is bit-identical —
    //    see the README migration table).
    let model = GeoModel::builder()
        .data(data.clone())
        .kernel("ugsm-s")
        .metric("euclidean")
        .variant(Variant::Exact)
        .bounds(vec![0.001; 3], vec![5.0; 3])
        .tol(1e-5)
        .tile_size(64)
        .build()?;
    let fit = model.fit(&exa)?;
    println!(
        "GeoModel fit: theta_hat = ({:.3}, {:.3}, {:.3}), loglik = {:.3}, {} iters, {:.4} s/iter",
        fit.theta[0], fit.theta[1], fit.theta[2], fit.loglik, fit.iters, fit.time_per_iter
    );

    // 4. exact_predict: krige 20 held-out locations.
    let train = exageostat::simulation::GeoData {
        locs: data.locs[..380].to_vec(),
        z: data.z[..380].to_vec(),
    };
    let target = &data.locs[380..];
    let pred = exa.exact_predict(&train, target, "ugsm-s", "euclidean", &fit.theta, true)?;
    let rmse: f64 = (pred
        .mean
        .iter()
        .zip(&data.z[380..])
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / 20.0)
        .sqrt();
    let base: f64 = (data.z[380..].iter().map(|t| t * t).sum::<f64>() / 20.0).sqrt();
    println!("kriging RMSE = {rmse:.4} (predict-zero baseline {base:.4})");
    assert!(rmse < base, "kriging must beat the trivial predictor");

    // 5./6. Three-layer parity + PJRT-backed MLE: only when the PJRT
    //    backend can actually be constructed (pjrt feature + artifacts +
    //    real xla crate); otherwise explain how to enable it.
    match backend::create_engine(Backend::Pjrt) {
        Ok(eng) => {
            // 5. Tile parity: the backend serves the lowered Pallas
            //    artifact for covered tiles (ugsm-s, Euclidean, square
            //    lowered sizes, half-integer nu) and falls back to the
            //    native kernels otherwise — so a zero diff certifies the
            //    engine contract; it is artifact-execution evidence only
            //    when the ts=64 artifact is in the manifest (aot.py
            //    always lowers ts 32 and 64).
            let theta_hi = [fit.theta[0], fit.theta[1], 0.5];
            let kernel = kernel_by_name("ugsm-s")?;
            let mut pjrt_tile = vec![0.0; 64 * 64];
            eng.fill_tile(
                kernel.as_ref(),
                &theta_hi,
                &data.locs,
                DistanceMetric::Euclidean,
                0,
                64,
                64,
                64,
                None,
                &mut pjrt_tile,
            );
            let mut native = vec![0.0; 64 * 64];
            fill_cov_tile(
                kernel.as_ref(),
                &theta_hi,
                &data.locs,
                DistanceMetric::Euclidean,
                0,
                64,
                64,
                64,
                &mut native,
            );
            let err = pjrt_tile
                .iter()
                .zip(&native)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            println!(
                "pjrt-engine tile vs native-tile max |diff| = {err:.2e} \
                 (pallas artifact when covered, native fallback otherwise)"
            );
            assert!(err < 1e-12);

            // 6. Three-layer MLE: the optimizer's objective is the
            //    engine's dense log-likelihood — the AOT-lowered L2 graph
            //    when `loglik_n256` is in the manifest (aot.py lowers it
            //    by default), the native dense path otherwise — Rust
            //    drives the whole search with Python nowhere on the path.
            let d256 = exa.simulate_data_exact("ugsm-s", &theta_true, "euclidean", 256, 1)?;
            let bounds = exageostat::optimizer::Bounds::new(vec![0.01; 3], vec![5.0; 3])?;
            let opts = exageostat::optimizer::OptOptions {
                tol: 1e-4,
                max_iters: 150,
                init: vec![0.01; 3],
                stop: None,
            };
            let k2 = kernel_by_name("ugsm-s")?;
            let r = exageostat::optimizer::minimize(
                exageostat::optimizer::Method::Bobyqa,
                |theta| {
                    match eng.loglik(
                        k2.as_ref(),
                        theta,
                        &d256.locs,
                        &d256.z,
                        DistanceMetric::Euclidean,
                    ) {
                        Ok(l) => -l.loglik,
                        Err(_) => f64::INFINITY,
                    }
                },
                bounds,
                &opts,
            );
            println!(
                "PJRT-engine MLE (n=256): theta_hat = ({:.3}, {:.3}, {:.3}), \
                 -loglik = {:.3}, {} iters @ {:.1} ms/iter",
                r.x[0],
                r.x[1],
                r.x[2],
                r.fx,
                r.iters,
                1e3 * r.time_per_iter
            );
            assert!(r.fx.is_finite());
        }
        Err(e) => {
            println!(
                "(PJRT backend unavailable: {e:#} — build with `--features pjrt`, point the \
                 `xla` path dependency at the real crate, and run `make artifacts` for the \
                 three-layer parity checks)"
            );
        }
    }

    exa.finalize();
    println!("quickstart OK");
    Ok(())
}
