//! Quickstart: the README example — simulate a Gaussian random field,
//! fit it by exact MLE, krige a held-out set, and (if `make artifacts`
//! has run) cross-check the covariance tile and the likelihood against
//! the AOT-compiled JAX/Pallas artifacts through PJRT.
//!
//! Run: `cargo run --release --example quickstart`

use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::runtime::{artifacts_available, PjrtEngine};
use exageostat::scheduler::pool::Policy;

fn main() -> anyhow::Result<()> {
    // 1. exageostat_init(hardware) — Example 1 of the paper.
    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ngpus: 0,
        ts: 64,
        pgrid: 1,
        qgrid: 1,
        policy: Policy::Prio,
    });

    // 2. simulate_data_exact: 400 locations, theta = (1, 0.1, 0.5).
    let theta_true = [1.0, 0.1, 0.5];
    let data = exa.simulate_data_exact("ugsm-s", &theta_true, "euclidean", 400, 0)?;
    println!("simulated n = {} (seed 0, theta = {theta_true:?})", data.n());

    // 3. exact_mle with the paper's optimization settings.
    let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], 1e-5, 0);
    let fit = exa.exact_mle(&data, "ugsm-s", "euclidean", &opt)?;
    println!(
        "exact_mle: theta_hat = ({:.3}, {:.3}, {:.3}), loglik = {:.3}, {} iters, {:.4} s/iter",
        fit.theta[0], fit.theta[1], fit.theta[2], fit.loglik, fit.iters, fit.time_per_iter
    );

    // 4. exact_predict: krige 20 held-out locations.
    let train = exageostat::simulation::GeoData {
        locs: data.locs[..380].to_vec(),
        z: data.z[..380].to_vec(),
    };
    let target = &data.locs[380..];
    let pred = exa.exact_predict(&train, target, "ugsm-s", "euclidean", &fit.theta, true)?;
    let rmse: f64 = (pred
        .mean
        .iter()
        .zip(&data.z[380..])
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / 20.0)
        .sqrt();
    let base: f64 = (data.z[380..].iter().map(|t| t * t).sum::<f64>() / 20.0).sqrt();
    println!("kriging RMSE = {rmse:.4} (predict-zero baseline {base:.4})");
    assert!(rmse < base, "kriging must beat the trivial predictor");

    // 5. Three-layer parity: Rust native vs AOT Pallas artifact via PJRT.
    if artifacts_available() {
        let eng = PjrtEngine::from_default()?;
        println!("PJRT platform: {}", eng.platform());
        // The Pallas artifact implements the half-integer closed forms
        // (nu in {0.5, 1.5, 2.5}); the Rust path handles general nu via
        // Bessel K.  Compare at the nearest half-integer smoothness.
        let theta_hi = [fit.theta[0], fit.theta[1], 0.5];
        let tile = eng.matern_tile(64, &data.locs[..64], &data.locs[64..128], &theta_hi)?;
        let kernel = exageostat::covariance::kernel_by_name("ugsm-s")?;
        let mut native = vec![0.0; 64 * 64];
        exageostat::covariance::fill_cov_tile(
            kernel.as_ref(),
            &theta_hi,
            &data.locs,
            exageostat::covariance::DistanceMetric::Euclidean,
            0,
            64,
            64,
            64,
            &mut native,
        );
        let err = tile
            .iter()
            .zip(&native)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        println!("pallas-tile vs native-tile max |diff| = {err:.2e}");
        assert!(err < 1e-12);
    } else {
        println!("(artifacts not built — run `make artifacts` for the PJRT parity check)");
    }

    // 6. Three-layer MLE: the optimizer's objective is the AOT-lowered
    //    L2 log-likelihood graph executed through PJRT — Rust drives the
    //    whole search with Python nowhere on the path.
    if artifacts_available() {
        let eng = PjrtEngine::from_default()?;
        let d256 = exa.simulate_data_exact("ugsm-s", &theta_true, "euclidean", 256, 1)?;
        let bounds = exageostat::optimizer::Bounds::new(vec![0.01; 3], vec![5.0; 3])?;
        let opts = exageostat::optimizer::OptOptions {
            tol: 1e-4,
            max_iters: 150,
            init: vec![0.01; 3],
        };
        let r = exageostat::optimizer::minimize(
            exageostat::optimizer::Method::Bobyqa,
            |theta| match eng.loglik(&d256.locs, &d256.z, theta) {
                Ok((ll, _, _)) => -ll,
                Err(_) => f64::INFINITY,
            },
            bounds,
            &opts,
        );
        println!(
            "PJRT-backed MLE (n=256, artifact loglik_n256): theta_hat = ({:.3}, {:.3}, {:.3}), \
             -loglik = {:.3}, {} iters @ {:.1} ms/iter",
            r.x[0],
            r.x[1],
            r.x[2],
            r.fx,
            r.iters,
            1e3 * r.time_per_iter
        );
        assert!(r.fx.is_finite());
    }

    exa.finalize();
    println!("quickstart OK");
    Ok(())
}
