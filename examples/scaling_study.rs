//! Scaling and structure study — regenerates **Fig 5** (execution time
//! per iteration vs n, ExaGeoStatR vs GeoR-like vs fields-like, plus the
//! ratio panel) and the **Fig 1** structure maps, and reports the TLR
//! compression profile.
//!
//! Run: `cargo run --release --example scaling_study -- [--quick]`

use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::baselines::dense_negloglik;
use exageostat::cli::Args;
use exageostat::covariance::DistanceMetric;
use exageostat::likelihood::{self, ExecCtx, Variant};
use exageostat::scheduler::pool::Policy;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let quick = args.has("quick");
    let sizes: Vec<usize> = if quick {
        vec![100, 400, 900]
    } else {
        vec![100, 400, 900, 1600, 2500]
    };
    let theta = [1.0, 0.1, 0.5];
    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ts: 160,
        ..Hardware::default()
    });

    // ----- Fig 5: time per likelihood iteration vs n --------------------
    println!("Fig 5 — time per iteration (seconds) vs n; ratio vs exageostat");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "n", "exageostat", "geor-like", "fields-like", "r_geor", "r_fields"
    );
    for &n in &sizes {
        let data = exa.simulate_data_exact("ugsm-s", &theta, "euclidean", n, 0)?;
        let problem = exageostat::likelihood::Problem {
            kernel: exageostat::covariance::kernel_by_name("ugsm-s")?.into(),
            locs: std::sync::Arc::new(data.locs.clone()),
            z: std::sync::Arc::new(data.z.clone()),
            metric: DistanceMetric::Euclidean,
        };
        let ctx = exa.ctx();
        // one warm-up + 3 timed evaluations each
        let time_it = |f: &mut dyn FnMut()| {
            f();
            let t0 = Instant::now();
            for _ in 0..3 {
                f();
            }
            t0.elapsed().as_secs_f64() / 3.0
        };
        let t_exa = time_it(&mut || {
            let _ = likelihood::loglik(&problem, &theta, Variant::Exact, &ctx).unwrap();
        });
        let t_geor = time_it(&mut || {
            let _ = dense_negloglik(&data.locs, &data.z, &theta, DistanceMetric::Euclidean);
        });
        // fields-like evaluates the same dense likelihood; its per-iter
        // advantage in the paper comes from not optimizing nu (fewer
        // gradient stencil points), which shows in iterations, not in the
        // single-evaluation cost.
        let t_fields = time_it(&mut || {
            let _ = dense_negloglik(&data.locs, &data.z, &theta, DistanceMetric::Euclidean);
        });
        println!(
            "{n:>6} {t_exa:>12.4} {t_geor:>12.4} {t_fields:>12.4} {:>8.2} {:>8.2}",
            t_geor / t_exa,
            t_fields / t_exa
        );
    }

    // ----- Fig 1: covariance structure maps ------------------------------
    println!("\nFig 1 — structure maps (n=1024, ts=128)");
    for (name, band) in [("(a) exact", None), ("(b) DST band=1", Some(1))] {
        println!("{name}");
        for row in likelihood::exact::structure_map(1024, 128, band) {
            println!("  {row}");
        }
    }
    println!("(d) MP band=1: same map as (b) with '.' tiles stored in f32");

    // ----- Fig 1(c): TLR rank map ----------------------------------------
    let n = 512;
    let data = exa.simulate_data_exact("ugsm-s", &theta, "euclidean", n, 7)?;
    let perm = exageostat::covariance::morton_perm(&data.locs);
    let locs: Vec<_> = perm.iter().map(|&i| data.locs[i]).collect();
    let problem = exageostat::likelihood::Problem {
        kernel: exageostat::covariance::kernel_by_name("ugsm-s")?.into(),
        locs: std::sync::Arc::new(locs),
        z: std::sync::Arc::new(data.z.clone()),
        metric: DistanceMetric::Euclidean,
    };
    let tlr = likelihood::tlr::generate(
        &problem,
        &theta,
        exageostat::linalg::lowrank::LrOpts {
            tol: 1e-7,
            max_rank: usize::MAX,
        },
        64,
    );
    println!("\nFig 1(c) — TLR per-tile ranks (n={n}, ts=64, tol=1e-7, morton-ordered)");
    for (i, row) in tlr.rank_map().iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|r| format!("{r:>3}")).collect();
        println!("  row {i}: [{}] + dense diag", cells.join(" "));
    }
    println!(
        "TLR storage: {} doubles vs {} dense ({:.1}% of dense)",
        tlr.storage_len(),
        tlr.dense_storage_len(),
        100.0 * tlr.storage_len() as f64 / tlr.dense_storage_len() as f64
    );

    // ----- Variant ablation on one fixed problem -------------------------
    println!("\nvariant ablation (n={n}, ts=64): loglik error vs exact + eval time");
    let ctx = ExecCtx::new(2, 64, Policy::Prio);
    let exact = likelihood::loglik(&problem, &theta, Variant::Exact, &ctx)?;
    for (name, v) in [
        ("exact", Variant::Exact),
        ("dst band=1", Variant::Dst { band: 1 }),
        ("dst band=2", Variant::Dst { band: 2 }),
        ("mp band=1", Variant::Mp { band: 1 }),
        (
            "tlr 1e-5",
            Variant::Tlr {
                tol: 1e-5,
                max_rank: usize::MAX,
            },
        ),
        (
            "tlr 1e-9",
            Variant::Tlr {
                tol: 1e-9,
                max_rank: usize::MAX,
            },
        ),
    ] {
        let t0 = Instant::now();
        let r = likelihood::loglik(&problem, &theta, v, &ctx)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {name:<12} loglik={:>12.4}  |err|={:>10.3e}  time={dt:.3}s",
            r.loglik,
            (r.loglik - exact.loglik).abs()
        );
    }
    let opt = MleOptions::new(vec![0.001; 3], vec![5.0; 3], 1e-4, 20);
    let _ = opt; // (MLE-level ablation lives in the table5 bench)
    exa.finalize();
    Ok(())
}
