//! Sea-surface-temperature tutorial — the **end-to-end driver** of this
//! reproduction (Section IV of the paper; Figs 8–9 and Table VI).
//!
//! Pipeline per day, exactly as the paper's tutorial:
//!   1. generate a day of synthetic Agulhas SST (mean gradient + Matérn
//!      GRF + land/orbital/cloud gaps — see DESIGN.md §5);
//!   2. drop days with > 50% missing;
//!   3. OLS-remove the linear mean `T ~ 1 + lon + lat`;
//!   4. `exact_mle` on the residuals (BOBYQA, bounds as in the paper);
//!   5. `exact_predict` to fill the orbital/cloud gaps (kriging);
//!   6. report Table-VI-style quantiles of the per-day estimates, plus a
//!      check the paper could not do: gap-filling RMSE vs the known truth
//!      and parameter recovery vs the generating values.
//!
//! Run: `cargo run --release --example sst_tutorial -- [--days 8] [--ny 24 --nx 80]`

use exageostat::api::{ExaGeoStat, Hardware, MleOptions};
use exageostat::cli::Args;
use exageostat::data::sst::{self, quantile, SstConfig};
use exageostat::scheduler::pool::Policy;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let days = args.get_usize("days", 8)?;
    let cfg = SstConfig {
        ny: args.get_usize("ny", 24)?,
        nx: args.get_usize("nx", 80)?,
        days,
        ..SstConfig::default()
    };
    println!(
        "SST tutorial: {} days on a {}x{} grid (paper: 331 days, 72x240; scaled for the testbed)",
        cfg.days, cfg.ny, cfg.nx
    );

    let exa = ExaGeoStat::init(Hardware {
        ncores: 2,
        ngpus: 0,
        ts: 160,
        pgrid: 1,
        qgrid: 1,
        policy: Policy::Prio,
    });

    let mut est_sigma = Vec::new();
    let mut est_beta = Vec::new();
    let mut est_nu = Vec::new();
    let mut rmse_krig_all = Vec::new();
    let mut rmse_mean_all = Vec::new();
    let mut fitted_days = 0;
    let t_start = Instant::now();

    for day in 0..cfg.days {
        let d = sst::generate_day(&cfg, day, &exa.ctx())?;
        let missing = 1.0 - d.valid_fraction();
        if missing > 0.5 {
            println!("day {day:>3}: {:.0}% missing — skipped (paper protocol)", 100.0 * missing);
            continue;
        }
        // Stage 1: OLS linear mean on (1, lon, lat).
        let (locs, z) = d.valid_observations();
        let (coef, resid) = sst::ols_linear_mean(&locs, &z);

        // Stage 2: exact MLE on the residual field.
        let train = exageostat::simulation::GeoData {
            locs: locs.clone(),
            z: resid.clone(),
        };
        // Paper: sigma/beta range (0.01, 20), nu range (0.01, 5),
        // tol 1e-4; we cap iterations like the timing comparison (20+)
        let opt = MleOptions::new(
            vec![0.01, 0.01, 0.01],
            vec![20.0, 20.0, 5.0],
            1e-4,
            args.get_usize("max-iters", 60)?,
        );
        let fit = exa.exact_mle(&train, "ugsm-s", "euclidean", &opt)?;
        est_sigma.push(fit.theta[0]);
        est_beta.push(fit.theta[1]);
        est_nu.push(fit.theta[2]);
        fitted_days += 1;

        // Stage 3: kriging the predictable gaps (orbit + cloud, not land).
        let (gap_locs, gap_truth) = d.predictable_gaps();
        let pred = exa.exact_predict(&train, &gap_locs, "ugsm-s", "euclidean", &fit.theta, false)?;
        let mut se_krig = 0.0;
        let mut se_mean = 0.0;
        for (k, s0) in gap_locs.iter().enumerate() {
            let mean_pred = coef[0] + coef[1] * s0.x + coef[2] * s0.y;
            let full_pred = mean_pred + pred.mean[k];
            se_krig += (full_pred - gap_truth[k]).powi(2);
            se_mean += (mean_pred - gap_truth[k]).powi(2);
        }
        let rmse_krig = (se_krig / gap_locs.len() as f64).sqrt();
        let rmse_mean = (se_mean / gap_locs.len() as f64).sqrt();
        rmse_krig_all.push(rmse_krig);
        rmse_mean_all.push(rmse_mean);

        println!(
            "day {day:>3}: n={:>5} miss={:>4.0}% theta=({:>5.2},{:>5.2},{:>4.2}) truth=({:>5.2},{:>5.2},{:>4.2}) gapRMSE {:.2} (mean-only {:.2}) [{} it, {:.2}s/it]",
            locs.len(),
            100.0 * missing,
            fit.theta[0], fit.theta[1], fit.theta[2],
            d.theta_true[0], d.theta_true[1], d.theta_true[2],
            rmse_krig, rmse_mean,
            fit.iters, fit.time_per_iter,
        );
    }

    // ----- Table VI: summary quantiles over fitted days ------------------
    println!("\nTable VI — summary of estimated parameters over {fitted_days} fitted days");
    println!(
        "{:>10} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "", "Min", "25% Q", "Median", "Mean", "75% Q", "Max"
    );
    for (name, vals) in [
        ("sigma_sq", &mut est_sigma),
        ("beta", &mut est_beta),
        ("nu", &mut est_nu),
    ] {
        vals.sort_by(|a, b| a.total_cmp(b));
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        println!(
            "{name:>10} {:>8.2} {:>8.2} {:>8.2} {mean:>8.2} {:>8.2} {:>8.2}",
            vals[0],
            quantile(vals, 0.25),
            quantile(vals, 0.5),
            quantile(vals, 0.75),
            vals[vals.len() - 1],
        );
    }

    // ----- Gap-filling skill (Fig 8's "fill the spatial images") ---------
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "\nkriging gap-fill RMSE: {:.3} C vs mean-only {:.3} C (improvement {:.1}%)",
        avg(&rmse_krig_all),
        avg(&rmse_mean_all),
        100.0 * (1.0 - avg(&rmse_krig_all) / avg(&rmse_mean_all))
    );
    assert!(
        avg(&rmse_krig_all) < avg(&rmse_mean_all),
        "kriging must improve on the linear mean alone"
    );
    println!("total wall time: {:.1}s", t_start.elapsed().as_secs_f64());
    exa.finalize();
    println!("sst_tutorial OK");
    Ok(())
}
