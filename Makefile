# ExaGeoStatR reproduction — build / test / artifact entry points.
#
#   make artifacts    lower the JAX/Pallas kernels to HLO-text artifacts
#                     (runs python/compile/aot.py once; needs JAX)
#   make test         tier-1 verify: release build + full Rust test suite
#   make bench-smoke  run every bench binary on tiny problem sizes
#   make fmt / lint   formatting and clippy, as CI runs them
#   make python-test  the python suite (skips cleanly without JAX)

ARTIFACT_DIR ?= artifacts
PYTHON ?= python3

BENCHES = fig3_shared_memory fig5_scaling_n fig6_accelerated \
          fig7_distributed table5_time_per_iter ablation_variants \
          serving_throughput kernel_roofline sst_scaling placement \
          faults

.PHONY: all test artifacts bench-smoke fmt lint doc python-test clean

all: test

# Tier-1 verify (ROADMAP.md): must pass on a clean machine with no
# Python, JAX, or XLA installed.
test:
	cargo build --release
	cargo test -q

# AOT-lower the L1/L2 kernels to $(ARTIFACT_DIR)/*.hlo.txt + manifest.txt
# (see rust/src/runtime/mod.rs; the PJRT backend loads these).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out $(abspath $(ARTIFACT_DIR))

# Smoke-run each bench binary in seconds: BENCH_QUICK shrinks every
# problem size (see rust/benches/bench_util.rs `quick()`).
# table5_time_per_iter also refreshes BENCH_mle_iter.json (per-variant
# time/iteration + EvalSession warm-vs-cold speedup telemetry);
# serving_throughput refreshes BENCH_serving.json (shared-runtime vs
# per-job-pool requests/sec + latency percentiles); kernel_roofline
# refreshes BENCH_kernels.json (per-kernel GFLOP/s, dispatched-SIMD vs
# forced-scalar, fused-vs-unfused warm eval per variant, MP-vs-exact
# time/eval — EXPERIMENTS.md §Kernel roofline); sst_scaling refreshes
# BENCH_sst_scaling.json (warm eval resident vs out-of-core budget vs
# MP on the SST day, with peak-resident and spill counters —
# EXPERIMENTS.md §SST workload scaling); placement refreshes
# BENCH_placement.json (cost-model placement vs class-blind scheduling
# on a cpu+slow pool, plus the heterogeneous DES projection ratio —
# EXPERIMENTS.md §Heterogeneous placement); faults refreshes
# BENCH_faults.json (warm eval under seeded fault injection at 0/1%/5%
# rates with retry, armed-vs-disarmed overhead ratio —
# EXPERIMENTS.md §Fault tolerance).  BENCH_OUT pins every
# bench's JSON to the repo root regardless of cargo's bench cwd, so the
# CI artifact glob and the regression gate always find them.  Ends
# with a smoke invocation of the `exageostat serve` subcommand.
bench-smoke:
	@for b in $(BENCHES); do \
		echo "== bench $$b (quick) =="; \
		BENCH_QUICK=1 BENCH_OUT=$(abspath .) cargo bench --bench $$b || exit 1; \
	done
	@echo "== serve smoke (file) =="
	@mkdir -p target
	@printf '%s\n%s\n%s\n' \
		'{"type":"simulate","n":100,"seed":1}' \
		'{"type":"mle","n":100,"seed":1,"max_iters":5}' \
		'{"type":"predict","n":100,"seed":1,"grid":5}' \
		> target/serve_smoke.jsonl
	cargo run --release -p exageostat -- serve \
		--requests target/serve_smoke.jsonl --clients 2 --ncores 2 --ts 50
	@echo "== serve smoke (stdin stream) =="
	@printf '%s\n%s\n' \
		'{"type":"simulate","n":100,"seed":2}' \
		'{"type":"mle","n":100,"seed":2,"max_iters":5}' \
		| cargo run --release -p exageostat -- serve \
		--stdin --clients 2 --ncores 2 --ts 50 --window 2

fmt:
	cargo fmt --all --check

lint: doc
	cargo clippy --all-targets -- -D warnings

# Public-API docs; fails on rustdoc warnings (broken links etc.), as CI
# runs it in the lint job.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

python-test:
	@if $(PYTHON) -c "import jax" 2>/dev/null; then \
		$(PYTHON) -m pytest python/tests -q; \
	else \
		echo "JAX not installed — python suite skipped"; \
	fi

clean:
	cargo clean
	rm -rf $(ARTIFACT_DIR)
	find python -name __pycache__ -type d -exec rm -rf {} + 2>/dev/null || true
